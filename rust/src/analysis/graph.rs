//! Crate-wide call graph over the lexed/scoped sources.
//!
//! [`CallGraph::build`] extracts per-fn call sites and Mutex
//! acquisition sites, resolves call targets, and exposes reachability
//! closures so the rules in [`super::rules`] can check contracts
//! *transitively* — a helper three calls below a `// lint: hot-path`
//! root is held to the same standard as the root.
//!
//! ## Resolution strategy (conservative over-approximation)
//!
//! Rust name resolution needs types; a lexical pass does not have them.
//! The graph therefore over-approximates — every call edge that *could*
//! bind to a crate fn is added — but first tries to *narrow* method
//! calls with lexical type facts:
//!
//! * `recv.name(…)` (method call) — the receiver's candidate types are
//!   recovered from `self` (impl owner), fn parameter annotations,
//!   `let` bindings (type annotations, `Type { … }` / `Type::assoc(…)`
//!   initializers, `vec!`, free-fn return types, method-call chains),
//!   struct-field declarations (`self.field`, `x.field`, struct
//!   destructuring `let Self { field, .. } = …`), `static` types,
//!   for-loop iterables, indexing (`xs[i].name(…)`) and call chains
//!   (`a.b(…).name(…)` via `b`'s declared return type). Trait-typed
//!   candidates expand to their crate implementors. Known crate types
//!   narrow the fan-out to their own impls; receivers that resolve to
//!   std-only types contribute **no** edge; untypable receivers keep
//!   the conservative every-same-named-method fan-out. Dot calls only
//!   ever bind fns that take a `self` receiver, and a method name no
//!   crate impl defines dot-callably is std-opaque even on an
//!   untypable receiver. Turbofish on an untypable receiver
//!   (`x.parse::<u32>()`) adds no edge: crate methods are monomorphic.
//! * `Type::name(…)` (capitalized qualifier) → every method named
//!   `name` whose impl owner is `Type`; `Self::name(…)` uses the
//!   caller's own impl owner.
//! * `a::b::name(…)` (lowercase qualifier) → every *free* fn named
//!   `name` in a module whose path ends with `a::b` (leading `crate`
//!   is stripped; a bare `self::name` resolves within the caller's
//!   module).
//! * `name(…)` (bare) → every free fn named `name`, in any module.
//!
//! Calls that resolve to nothing (std/foreign fns) add no edge: the
//! analysis is whole-crate, not whole-program. Call sites inside
//! closures attribute to the innermost enclosing `fn`. Fns inside
//! `#[cfg(test)]` modules are excluded from the graph entirely so test
//! helpers neither shadow nor inherit production contracts.
//!
//! The remaining cost of over-approximation is spurious membership on
//! genuinely untypable receivers; the escape hatch is a written
//! contract — a line-level `// lint: allow(<rule>) — why` on the call
//! site prunes that edge from `<rule>`'s closure, and
//! `// lint: boundary(<rule>) — why` on a fn stops descent at it. Both
//! count toward the suppression-debt baseline in `LINT.json`.
//!
//! ## Lock sites
//!
//! `recv.lock()` with *empty* parens is recorded as a Mutex acquisition
//! (an argument-taking `.lock(x)` is an ordinary method call, e.g. the
//! photonic `FeedbackController::lock`). Mutex identity is lexical:
//! `SCREAMING_CASE` receivers (statics) are global; `self.field.lock()`
//! is keyed `Owner.field`; anything else is keyed `module.receiver`.
//! Direct acquisitions are assumed held until the end of the fn (no
//! drop tracking). A callee's transitive acquisitions order *after*
//! whatever the caller already holds (momentary edges), but they stay
//! in the caller's held set only when the callee's return type names a
//! `*Guard*` type — a lock-and-release helper does not leak its locks
//! into every caller, while a guard-returning accessor does.

use std::collections::{BTreeMap, BTreeSet, VecDeque};

use super::ast::{Function, SourceFile};
use super::lexer::TokKind;

/// One graph node: a production `fn` item.
#[derive(Debug)]
pub struct Node {
    /// Index into the file slice the graph was built over.
    pub file: usize,
    /// Index into that file's `fns`.
    pub func: usize,
    /// `module::path::Owner::name` display name.
    pub qual: String,
}

/// One body event, in token order. The order matters only for the
/// lock-order rule; call edges ignore it.
#[derive(Debug)]
pub enum Event {
    /// `mutex.lock()` with the lexical mutex identity and source line.
    Acquire { mutex: String, line: u32 },
    /// A resolved call edge. One call site with `k` candidates emits
    /// `k` events on the same line.
    Call { callee: usize, line: u32 },
}

/// The crate call graph plus per-node lock/call event streams.
#[derive(Debug)]
pub struct CallGraph {
    pub nodes: Vec<Node>,
    /// Per node: token-ordered acquire/call events.
    pub events: Vec<Vec<Event>>,
    /// Distinct (caller, callee) pairs.
    pub edge_count: usize,
    /// Per file, per token: the node whose fn innermost-encloses the
    /// token (`None` for top-level tokens and test code).
    tok_node: Vec<Vec<Option<usize>>>,
}

/// A reachability closure for one rule, with parent pointers for
/// via-path diagnostics and the suppressions spent building it.
#[derive(Debug)]
pub struct Closure {
    pub member: Vec<bool>,
    parent: Vec<usize>,
    pub roots: Vec<usize>,
    /// Nodes whose `boundary(<rule>)` pragma stopped descent.
    pub boundaries: BTreeSet<usize>,
    /// Call-site lines whose `allow(<rule>)` pragma pruned an edge:
    /// (caller node, line).
    pub pruned: BTreeSet<(usize, u32)>,
}

/// A potential lock-ordering constraint: somewhere, `a` is held while
/// `b` is acquired.
#[derive(Debug)]
pub struct OrderEdge {
    pub a: String,
    pub b: String,
    /// Witness: the fn and line of the second acquisition.
    pub node: usize,
    pub line: u32,
}

const NO_PARENT: usize = usize::MAX;

/// Rust keywords that may precede `(` without being a call.
const KEYWORDS: [&str; 22] = [
    "if", "else", "while", "match", "return", "in", "for", "loop", "move", "box",
    "ref", "mut", "as", "let", "fn", "impl", "use", "pub", "where", "unsafe",
    "await", "dyn",
];

/// Well-known std type names: receivers narrowing to these (and only
/// these) contribute no call edge — the crate defines no methods on
/// them.
const STD_TYPES: [&str; 59] = [
    "String", "Vec", "VecDeque", "HashMap", "HashSet", "BTreeMap", "BTreeSet",
    "Box", "Arc", "Rc", "RefCell", "Cell", "Mutex", "RwLock", "Condvar",
    "MutexGuard", "Option", "Result", "Some", "Ok", "Err", "Instant",
    "Duration", "SystemTime", "PathBuf", "Path", "File", "TcpStream",
    "TcpListener", "UdpSocket", "BufReader", "BufWriter", "AtomicBool",
    "AtomicUsize", "AtomicU32", "AtomicU64", "AtomicI64", "JoinHandle",
    "Sender", "Receiver", "SyncSender", "Ordering", "Range", "Builder",
    "Command", "Child", "Stdio", "Output", "Error", "ErrorKind", "OsString",
    "ExitStatus", "IpAddr", "SocketAddr", "Iterator", "Cow", "Wrapping",
    "Thread", "Barrier",
];

/// Is `name` a std-ish type for narrowing purposes? Primitives and
/// generic parameters lex as lowercase/short idents; `__std` is the
/// opaque marker for std method-chain results.
fn std_like(name: &str) -> bool {
    name == "__std"
        || STD_TYPES.contains(&name)
        || name.chars().next().map_or(true, |c| c.is_lowercase())
}

/// A `let`/`for`/destructuring binding inside one fn body: where the
/// name was bound and the lexical type hint attached to it.
#[derive(Debug, Clone)]
struct Binding {
    pos: usize,
    name: String,
    hint: Hint,
}

/// Lexical type hint for a binding, resolved lazily (and recursively,
/// depth-capped) by [`Resolver::hint_types`].
#[derive(Debug, Clone)]
enum Hint {
    /// A concrete type name (`let x: Tile = …`, `let x = Tile { … }`).
    Ty(String),
    /// The declared type(s) of a struct field with this name.
    FieldRef(String),
    /// Another local/param name bound before `pos`.
    Var(String, usize),
    /// `base(.field)*[i]*.meth(…)`: the return type of `meth` on the
    /// receiver's hinted type. `(base, fields, meth, pos)`.
    MCall(String, Vec<String>, String, usize),
    /// A free-fn call initializer: the union of its return types.
    FreeFn(String),
    /// `Type::assoc(…)`: `assoc`'s return type on `Type` (falls back to
    /// `Type` itself — constructors conventionally return `Self`).
    Assoc(String, String),
    Unknown,
}

impl CallGraph {
    pub fn build(files: &[SourceFile]) -> CallGraph {
        let mod_paths: Vec<Vec<String>> =
            files.iter().map(|f| module_path(&f.path)).collect();

        // Nodes: every non-test fn, keyed for name lookup.
        let mut nodes = Vec::new();
        let mut free: BTreeMap<String, Vec<usize>> = BTreeMap::new();
        let mut methods: BTreeMap<String, Vec<usize>> = BTreeMap::new();
        for (fi, f) in files.iter().enumerate() {
            for (gi, func) in f.fns.iter().enumerate() {
                if f.in_test(func.body.0) {
                    continue;
                }
                let idx = nodes.len();
                let mut qual = mod_paths[fi].join("::");
                if let Some(o) = &func.owner {
                    if !qual.is_empty() {
                        qual.push_str("::");
                    }
                    qual.push_str(o);
                }
                if !qual.is_empty() {
                    qual.push_str("::");
                }
                qual.push_str(&func.name);
                match &func.owner {
                    Some(_) => methods.entry(func.name.clone()).or_default().push(idx),
                    None => free.entry(func.name.clone()).or_default().push(idx),
                }
                nodes.push(Node { file: fi, func: gi, qual });
            }
        }

        // Crate-wide type knowledge for receiver narrowing.
        let mut fields: BTreeMap<String, BTreeSet<String>> = BTreeMap::new();
        let mut statics: BTreeMap<String, String> = BTreeMap::new();
        let mut traits: BTreeMap<String, BTreeSet<String>> = BTreeMap::new();
        let mut owners: BTreeSet<String> = BTreeSet::new();
        let mut crate_types: BTreeSet<String> = BTreeSet::new();
        for f in files {
            for (k, v) in &f.fields {
                fields.entry(k.clone()).or_default().extend(v.iter().cloned());
            }
            for (k, v) in &f.statics {
                statics.insert(k.clone(), v.clone());
            }
            crate_types.extend(f.types.iter().cloned());
            for b in &f.impls {
                owners.insert(b.ty.clone());
                if let Some(tr) = &b.trait_of {
                    traits.entry(tr.clone()).or_default().insert(b.ty.clone());
                }
            }
        }

        // Innermost-fn attribution per token, mapped to node indices.
        let mut tok_node: Vec<Vec<Option<usize>>> = Vec::with_capacity(files.len());
        let mut fn_to_node: BTreeMap<(usize, usize), usize> = BTreeMap::new();
        for (ni, n) in nodes.iter().enumerate() {
            fn_to_node.insert((n.file, n.func), ni);
        }
        for (fi, f) in files.iter().enumerate() {
            let mut stamp: Vec<Option<usize>> = vec![None; f.toks.len()];
            // widest ranges first so the innermost stamp wins
            let mut order: Vec<usize> = (0..f.fns.len()).collect();
            order.sort_by_key(|&gi| {
                std::cmp::Reverse(f.fns[gi].body.1 - f.fns[gi].body.0)
            });
            for gi in order {
                let node = fn_to_node.get(&(fi, gi)).copied();
                let (s, e) = f.fns[gi].body;
                for t in stamp.iter_mut().take(e).skip(s) {
                    *t = node;
                }
            }
            tok_node.push(stamp);
        }

        // Per-node binding extraction (pure per-file, so precomputed).
        let bindings: Vec<Vec<Binding>> = nodes
            .iter()
            .map(|n| fn_bindings(&files[n.file], &files[n.file].fns[n.func]))
            .collect();

        let resolver = Resolver {
            files,
            mod_paths: &mod_paths,
            nodes: &nodes,
            free,
            methods,
            fields,
            statics,
            traits,
            owners,
            crate_types,
            bindings,
        };

        // Event extraction.
        let mut events: Vec<Vec<Event>> = (0..nodes.len()).map(|_| Vec::new()).collect();
        let mut edges: BTreeSet<(usize, usize)> = BTreeSet::new();
        for (fi, f) in files.iter().enumerate() {
            for i in 0..f.toks.len() {
                let Some(ni) = tok_node[fi][i] else { continue };
                let t = &f.toks[i];
                if t.kind != TokKind::Ident {
                    continue;
                }
                if let Some(mutex) = lock_acquire(f, i, &nodes[ni], &mod_paths[fi]) {
                    events[ni].push(Event::Acquire { mutex, line: t.line });
                    continue;
                }
                if !call_parens_follow(f, i) {
                    continue;
                }
                for c in resolver.resolve(f, i, ni) {
                    edges.insert((ni, c));
                    events[ni].push(Event::Call { callee: c, line: t.line });
                }
            }
        }

        CallGraph { nodes, events, edge_count: edges.len(), tok_node }
    }

    /// Node attribution for token `i` of file `fi`.
    pub fn node_at(&self, fi: usize, i: usize) -> Option<usize> {
        self.tok_node[fi].get(i).copied().flatten()
    }

    /// Every distinct mutex identity the graph observed being acquired.
    pub fn mutexes(&self) -> BTreeSet<String> {
        self.events
            .iter()
            .flatten()
            .filter_map(|e| match e {
                Event::Acquire { mutex, .. } => Some(mutex.clone()),
                Event::Call { .. } => None,
            })
            .collect()
    }

    /// BFS reachability from `roots`, honoring `boundary(rule)` fn
    /// pragmas and call-site `allow(rule)` line pragmas (written
    /// contract required for both).
    pub fn closure(
        &self,
        files: &[SourceFile],
        roots: &[usize],
        rule: &str,
    ) -> Closure {
        let n = self.nodes.len();
        let mut c = Closure {
            member: vec![false; n],
            parent: vec![NO_PARENT; n],
            roots: Vec::new(),
            boundaries: BTreeSet::new(),
            pruned: BTreeSet::new(),
        };
        let mut queue = VecDeque::new();
        for &r in roots {
            if !c.member[r] {
                c.member[r] = true;
                c.roots.push(r);
                queue.push_back(r);
            }
        }
        while let Some(ni) = queue.pop_front() {
            let caller_file = &files[self.nodes[ni].file];
            for ev in &self.events[ni] {
                let Event::Call { callee, line } = ev else { continue };
                let suppressed = caller_file
                    .line_pragma(*line, "allow")
                    .is_some_and(|p| p.arg == rule && !p.note.is_empty());
                if suppressed {
                    c.pruned.insert((ni, *line));
                    continue;
                }
                if c.member[*callee] {
                    continue;
                }
                let cn = &self.nodes[*callee];
                if files[cn.file].fns[cn.func].boundary(rule) {
                    c.boundaries.insert(*callee);
                    continue;
                }
                c.member[*callee] = true;
                c.parent[*callee] = ni;
                queue.push_back(*callee);
            }
        }
        c
    }

    /// All mutexes each node may acquire, directly or transitively
    /// (fixpoint iteration — cycle-safe).
    pub fn lock_sets(&self) -> Vec<BTreeSet<String>> {
        let n = self.nodes.len();
        let mut sets: Vec<BTreeSet<String>> = vec![BTreeSet::new(); n];
        for (ni, evs) in self.events.iter().enumerate() {
            for ev in evs {
                if let Event::Acquire { mutex, .. } = ev {
                    sets[ni].insert(mutex.clone());
                }
            }
        }
        loop {
            let mut changed = false;
            for ni in 0..n {
                let mut add: Vec<String> = Vec::new();
                for ev in &self.events[ni] {
                    if let Event::Call { callee, .. } = ev {
                        for m in &sets[*callee] {
                            if !sets[ni].contains(m) {
                                add.push(m.clone());
                            }
                        }
                    }
                }
                for m in add {
                    sets[ni].insert(m);
                    changed = true;
                }
            }
            if !changed {
                return sets;
            }
        }
    }

    /// Every "holds `a`, acquires `b`" pair, with its first witness.
    /// A `// lint: allow(lock-order) — why` on the second acquisition's
    /// line drops the pair (the suppression is counted by the caller).
    ///
    /// A callee's acquisitions order after the caller's held set at the
    /// call site, but join the held set only when the callee *returns a
    /// guard* (its return type names a `*Guard*` ident): plain helpers
    /// release their locks on return.
    pub fn order_edges(
        &self,
        files: &[SourceFile],
        suppressed: &mut usize,
    ) -> Vec<OrderEdge> {
        let sets = self.lock_sets();
        let mut first: BTreeMap<(String, String), (usize, u32)> = BTreeMap::new();
        for (ni, evs) in self.events.iter().enumerate() {
            let f = &files[self.nodes[ni].file];
            let mut held: BTreeSet<String> = BTreeSet::new();
            for ev in evs {
                let (acquired, line, escapes): (Vec<String>, u32, bool) = match ev {
                    Event::Acquire { mutex, line } => {
                        (vec![mutex.clone()], *line, true)
                    }
                    Event::Call { callee, line } => {
                        let cn = &self.nodes[*callee];
                        (
                            sets[*callee].iter().cloned().collect(),
                            *line,
                            files[cn.file].fns[cn.func].ret_guard,
                        )
                    }
                };
                if acquired.is_empty() {
                    continue;
                }
                let allowed = f
                    .line_pragma(line, "allow")
                    .is_some_and(|p| p.arg == "lock-order" && !p.note.is_empty());
                if allowed && !held.is_empty() {
                    *suppressed += 1;
                }
                if !allowed {
                    for a in &held {
                        for b in &acquired {
                            if a != b {
                                first
                                    .entry((a.clone(), b.clone()))
                                    .or_insert((ni, line));
                            }
                        }
                    }
                }
                if escapes {
                    held.extend(acquired);
                }
            }
        }
        first
            .into_iter()
            .map(|((a, b), (node, line))| OrderEdge { a, b, node, line })
            .collect()
    }
}

impl Closure {
    /// Root-to-`n` path (inclusive) for via-path messages.
    pub fn trail(&self, mut n: usize) -> Vec<usize> {
        let mut path = vec![n];
        while self.parent[n] != NO_PARENT {
            n = self.parent[n];
            path.push(n);
        }
        path.reverse();
        path
    }
}

/// `serve/net.rs` → `["serve", "net"]`; `mod.rs`/`lib.rs`/`main.rs`
/// collapse into their parent.
fn module_path(path: &str) -> Vec<String> {
    let trimmed = path.strip_suffix(".rs").unwrap_or(path);
    let mut segs: Vec<String> =
        trimmed.split('/').filter(|s| !s.is_empty()).map(String::from).collect();
    if matches!(segs.last().map(String::as_str), Some("mod" | "lib" | "main")) {
        segs.pop();
    }
    segs
}

/// Does a call-argument list follow the ident at `i` (directly or via
/// turbofish `::<…>(`)?
fn call_parens_follow(f: &SourceFile, i: usize) -> bool {
    let Some(j) = f.sig_at(i + 1) else { return false };
    if f.toks[j].is_punct('(') {
        return true;
    }
    if !f.toks[j].is_punct(':') {
        return false;
    }
    let Some(j2) = f.sig_at(j + 1) else { return false };
    if !f.toks[j2].is_punct(':') {
        return false;
    }
    let Some(j3) = f.sig_at(j2 + 1) else { return false };
    if !f.toks[j3].is_punct('<') {
        return false;
    }
    match f.skip_angles(j3) {
        Some(k) => f.sig_at(k).is_some_and(|x| f.toks[x].is_punct('(')),
        None => false,
    }
}

/// If the ident at `i` is a `recv.lock()` acquisition (empty parens),
/// return the lexical mutex identity.
fn lock_acquire(
    f: &SourceFile,
    i: usize,
    node: &Node,
    mod_path: &[String],
) -> Option<String> {
    if !f.toks[i].is_ident("lock") {
        return None;
    }
    let open = f.sig_at(i + 1)?;
    if !f.toks[open].is_punct('(') {
        return None;
    }
    let close = f.sig_at(open + 1)?;
    if !f.toks[close].is_punct(')') {
        return None;
    }
    let dot = f.sig_before(i.checked_sub(1)?)?;
    if !f.toks[dot].is_punct('.') {
        return None;
    }
    let r = f.sig_before(dot.checked_sub(1)?)?;
    if f.toks[r].kind != TokKind::Ident {
        return None; // `expr().lock()` — receiver not nameable, skip
    }
    let recv = f.toks[r].text.as_str();
    if recv
        .chars()
        .all(|c| c.is_ascii_uppercase() || c.is_ascii_digit() || c == '_')
    {
        return Some(recv.to_string()); // a static: globally named
    }
    // `self.field.lock()` keys by the impl owner; otherwise by module.
    let self_field = f
        .sig_before(r.checked_sub(1).unwrap_or(0))
        .filter(|&d| f.toks[d].is_punct('.'))
        .and_then(|d| f.sig_before(d.checked_sub(1)?))
        .is_some_and(|s| f.toks[s].is_ident("self"));
    let scope = if self_field {
        node.qual
            .rsplit("::")
            .nth(1)
            .unwrap_or("crate")
            .to_string()
    } else {
        mod_path.last().cloned().unwrap_or_else(|| "crate".to_string())
    };
    Some(format!("{scope}.{recv}"))
}

/// The path head two significant tokens back, if `i` is reached via
/// `Head::ident` (returns the text of `Head`).
fn path_head<'a>(f: &'a SourceFile, i: usize) -> Option<&'a str> {
    let c1 = f.sig_before(i.checked_sub(1)?)?;
    if !f.toks[c1].is_punct(':') {
        return None;
    }
    let c2 = f.sig_before(c1.checked_sub(1)?)?;
    if !f.toks[c2].is_punct(':') {
        return None;
    }
    let h = f.sig_before(c2.checked_sub(1)?)?;
    (f.toks[h].kind == TokKind::Ident).then(|| f.toks[h].text.as_str())
}

// ------------------------------------------------------------ bindings

/// Every `let`/`for`/destructuring binding in `func`'s body, in token
/// order, with its lexical type hint.
fn fn_bindings(f: &SourceFile, func: &Function) -> Vec<Binding> {
    let (s, e) = func.body;
    let mut out = Vec::new();
    let mut k = s;
    while k < e {
        if f.toks[k].is_ident("for") {
            if let Some(b) = for_binding(f, k) {
                out.push(b);
            }
            k += 1;
            continue;
        }
        if !f.toks[k].is_ident("let") {
            k += 1;
            continue;
        }
        let mut j = f.sig_at(k + 1);
        if j.is_some_and(|x| f.toks[x].is_ident("mut")) {
            j = f.sig_at(j.unwrap() + 1);
        }
        let Some(j) = j.filter(|&x| f.toks[x].kind == TokKind::Ident) else {
            k += 1;
            continue;
        };
        let name = f.toks[j].text.clone();
        let nxt = f.sig_at(j + 1);
        // `let Type { a, b: c, .. } = …` struct destructuring: each
        // bound name carries its source field's declared type —
        // `let Self { snaps, .. } = self` types `snaps` exactly like
        // `self.snaps`
        if nxt.is_some_and(|x| f.toks[x].is_punct('{'))
            && (name == "Self"
                || name.chars().next().is_some_and(|c| c.is_uppercase()))
        {
            k = destructure_bindings(f, nxt.unwrap(), &mut out);
            continue;
        }
        // `let Some(x) =` tuple-pattern destructuring: no hint
        if nxt.is_some_and(|x| f.toks[x].is_punct('(') || f.toks[x].is_punct('{')) {
            k += 1;
            continue;
        }
        if nxt.is_some_and(|x| f.toks[x].is_punct(':')) {
            let colon = nxt.unwrap();
            if f.sig_at(colon + 1).is_some_and(|x| f.toks[x].is_punct(':')) {
                k += 1; // `let X::Variant` pattern — not a binding
                continue;
            }
            let (ty, after) = f.type_run_last_ident(colon + 1, "=;");
            out.push(Binding {
                pos: j,
                name,
                hint: ty.map(Hint::Ty).unwrap_or(Hint::Unknown),
            });
            k = after;
            continue;
        }
        let Some(eq) = nxt.filter(|&x| f.toks[x].is_punct('=')) else {
            k = j + 1;
            continue;
        };
        let hint = init_hint(f, eq);
        out.push(Binding { pos: j, name, hint });
        k = eq + 1;
    }
    out
}

/// Bind the names of a `Type { a, b: c, .. }` destructuring pattern
/// whose `{` sits at `brace`; returns the resume index past the `}`.
fn destructure_bindings(f: &SourceFile, brace: usize, out: &mut Vec<Binding>) -> usize {
    let mut segs: Vec<Vec<Option<usize>>> = Vec::new();
    let mut cur: Vec<Option<usize>> = Vec::new();
    let mut kk = brace + 1;
    let mut depth = 1i32;
    let n = f.toks.len();
    while kk < n && depth > 0 {
        let t = &f.toks[kk];
        match t.punct() {
            Some('(') | Some('{') | Some('[') => {
                depth += 1;
                cur.push(None);
            }
            Some(')') | Some('}') | Some(']') => {
                depth -= 1;
                if depth == 0 {
                    break;
                }
                cur.push(None);
            }
            Some(',') if depth == 1 => segs.push(std::mem::take(&mut cur)),
            _ => {
                if !t.is_comment() {
                    cur.push(Some(kk));
                }
            }
        }
        kk += 1;
    }
    segs.push(cur);
    for seg in segs {
        let seg: Vec<usize> = seg
            .into_iter()
            .flatten()
            .filter(|&x| {
                !(f.toks[x].kind == TokKind::Ident
                    && matches!(f.toks[x].text.as_str(), "ref" | "mut"))
            })
            .collect();
        if seg.len() == 1 && f.toks[seg[0]].kind == TokKind::Ident {
            out.push(Binding {
                pos: seg[0],
                name: f.toks[seg[0]].text.clone(),
                hint: Hint::FieldRef(f.toks[seg[0]].text.clone()),
            });
        } else if seg.len() == 3
            && f.toks[seg[0]].kind == TokKind::Ident
            && f.toks[seg[1]].is_punct(':')
            && f.toks[seg[2]].kind == TokKind::Ident
        {
            out.push(Binding {
                pos: seg[2],
                name: f.toks[seg[2]].text.clone(),
                hint: Hint::FieldRef(f.toks[seg[0]].text.clone()),
            });
        }
    }
    kk + 1
}

/// `for name in iterable` — bind `name` to the iterable's hint
/// (`self.field` → field types; a plain local → that local's hint).
fn for_binding(f: &SourceFile, k: usize) -> Option<Binding> {
    let j = f.sig_at(k + 1)?;
    if f.toks[j].kind != TokKind::Ident {
        return None;
    }
    let name = f.toks[j].text.clone();
    let kw = f.sig_at(j + 1)?;
    if !f.toks[kw].is_ident("in") {
        return None;
    }
    let mut v = f.sig_at(kw + 1);
    while v.is_some_and(|x| f.toks[x].is_punct('&') || f.toks[x].is_ident("mut")) {
        v = f.sig_at(v.unwrap() + 1);
    }
    let v = v.filter(|&x| f.toks[x].kind == TokKind::Ident)?;
    if f.toks[v].is_ident("self") {
        let fld = f
            .sig_at(v + 1)
            .filter(|&d| f.toks[d].is_punct('.'))
            .and_then(|d| f.sig_at(d + 1))
            .filter(|&x| f.toks[x].kind == TokKind::Ident);
        if let Some(fl) = fld {
            return Some(Binding {
                pos: j,
                name,
                hint: Hint::FieldRef(f.toks[fl].text.clone()),
            });
        }
        return Some(Binding { pos: j, name, hint: Hint::Unknown });
    }
    Some(Binding {
        pos: j,
        name,
        hint: Hint::Var(f.toks[v].text.clone(), v),
    })
}

/// Type hint from the tokens after `=` in a `let` initializer.
fn init_hint(f: &SourceFile, eq: usize) -> Hint {
    let Some(v) = f.sig_at(eq + 1) else { return Hint::Unknown };
    let t = &f.toks[v];
    if t.is_ident("vec") {
        return Hint::Ty("Vec".to_string());
    }
    if t.kind != TokKind::Ident || KEYWORDS.contains(&t.text.as_str()) {
        return Hint::Unknown;
    }
    let name = t.text.clone();
    if name.chars().next().is_some_and(|c| c.is_uppercase()) {
        // `Type::assoc(…)` — the associated fn's return type; every
        // other `Type…` initializer (struct literal, tuple ctor, plain
        // path) hints the type itself
        let assoc = f
            .sig_at(v + 1)
            .filter(|&x| f.toks[x].is_punct(':'))
            .and_then(|x| f.sig_at(x + 1))
            .filter(|&x| f.toks[x].is_punct(':'))
            .and_then(|x| f.sig_at(x + 1))
            .filter(|&m| f.toks[m].kind == TokKind::Ident && call_parens_follow(f, m));
        if let Some(m) = assoc {
            return Hint::Assoc(name, f.toks[m].text.clone());
        }
        return Hint::Ty(name);
    }
    if call_parens_follow(f, v) {
        return Hint::FreeFn(name);
    }
    chain_hint(f, v)
}

/// Hint for `base(.field)*[i]*.method(…)` initializers: the method's
/// return type on the receiver's hinted type.
fn chain_hint(f: &SourceFile, v: usize) -> Hint {
    let base = f.toks[v].text.clone();
    let mut j = v;
    let mut flds: Vec<String> = Vec::new();
    loop {
        let Some(nxt) = f.sig_at(j + 1) else { return Hint::Unknown };
        let t = &f.toks[nxt];
        if t.is_punct('[') {
            match f.match_bracket_fwd(nxt) {
                Some(close) => {
                    j = close;
                    continue;
                }
                None => return Hint::Unknown,
            }
        }
        if !t.is_punct('.') {
            return Hint::Unknown;
        }
        let Some(m) =
            f.sig_at(nxt + 1).filter(|&x| f.toks[x].kind == TokKind::Ident)
        else {
            return Hint::Unknown;
        };
        if f.sig_at(m + 1).is_some_and(|x| f.toks[x].is_punct('(')) {
            return Hint::MCall(base, flds, f.toks[m].text.clone(), v);
        }
        flds.push(f.toks[m].text.clone());
        j = m;
    }
}

// ------------------------------------------------------------ resolver

/// Build-time resolution context: crate-wide name tables plus the
/// per-node binding hints.
struct Resolver<'a> {
    files: &'a [SourceFile],
    mod_paths: &'a [Vec<String>],
    nodes: &'a [Node],
    free: BTreeMap<String, Vec<usize>>,
    methods: BTreeMap<String, Vec<usize>>,
    fields: BTreeMap<String, BTreeSet<String>>,
    statics: BTreeMap<String, String>,
    /// Trait name → crate types implementing it.
    traits: BTreeMap<String, BTreeSet<String>>,
    /// Every impl-block base type name.
    owners: BTreeSet<String>,
    /// Every `struct`/`enum` name declared in the crate.
    crate_types: BTreeSet<String>,
    /// Per node: the bindings of its fn body.
    bindings: Vec<Vec<Binding>>,
}

fn set1(name: String) -> BTreeSet<String> {
    let mut s = BTreeSet::new();
    s.insert(name);
    s
}

impl Resolver<'_> {
    fn func(&self, ni: usize) -> &Function {
        let n = &self.nodes[ni];
        &self.files[n.file].fns[n.func]
    }

    fn method_cands(&self, name: &str) -> &[usize] {
        self.methods.get(name).map(Vec::as_slice).unwrap_or(&[])
    }

    /// Candidate receiver types expanded through the trait map: a
    /// trait-named candidate becomes its implementors.
    fn trait_owners(&self, cands: &BTreeSet<String>) -> BTreeSet<String> {
        let mut owners = BTreeSet::new();
        for c in cands {
            match self.traits.get(c) {
                Some(impls) => owners.extend(impls.iter().cloned()),
                None => {
                    owners.insert(c.clone());
                }
            }
        }
        owners
    }

    /// A candidate's declared return type, with `Self` mapped to its
    /// impl owner.
    fn ret_of(&self, c: usize) -> Option<String> {
        let func = self.func(c);
        if func.ret_ty.as_deref() == Some("Self") {
            return func.owner.clone();
        }
        func.ret_ty.clone()
    }

    /// Impl fns named `name` that take a `self` receiver: dot syntax
    /// can only ever invoke those, so associated constructors
    /// (`Type::new`) never join a method-call fan-out.
    fn dot_methods(&self, name: &str) -> Vec<usize> {
        self.method_cands(name)
            .iter()
            .copied()
            .filter(|&c| self.func(c).has_self)
            .collect()
    }

    /// Result type(s) of calling `meth` on a receiver whose candidate
    /// types are `cands`. `__std` marks a std-opaque result.
    fn method_ret(
        &self,
        cands: Option<&BTreeSet<String>>,
        meth: &str,
        depth: u32,
    ) -> Option<BTreeSet<String>> {
        if depth > 4 {
            return None;
        }
        if self.dot_methods(meth).is_empty() {
            // no crate impl defines a self-taking `meth`: whatever the
            // receiver is, the call resolves to std (or a derived
            // trait), so the chain result is std-opaque even with an
            // untypable base
            return Some(set1("__std".to_string()));
        }
        let cands = cands?;
        let owners = self.trait_owners(cands);
        let mut tys = BTreeSet::new();
        for &c in self.method_cands(meth) {
            if self.func(c).owner.as_ref().is_some_and(|o| owners.contains(o)) {
                if let Some(r) = self.ret_of(c) {
                    tys.insert(r);
                }
            }
        }
        if !tys.is_empty() {
            return Some(tys);
        }
        if meth == "clone" {
            return Some(cands.clone());
        }
        if owners
            .iter()
            .all(|c| std_like(c) || self.crate_types.contains(c))
        {
            // std (or derived) method on a known type: std-opaque
            return Some(set1("__std".to_string()));
        }
        None
    }

    /// Resolve a binding hint to a set of type names (`None` when
    /// untypable). Depth-capped: hints chain through other bindings.
    fn hint_types(
        &self,
        caller: Option<usize>,
        hint: &Hint,
        depth: u32,
    ) -> Option<BTreeSet<String>> {
        if depth > 4 {
            return None;
        }
        match hint {
            Hint::Ty(t) => Some(set1(t.clone())),
            Hint::FieldRef(fname) => self.fields.get(fname).cloned(),
            Hint::Var(name, pos) => {
                let caller = caller?;
                for b in self.bindings[caller].iter().rev() {
                    if &b.name == name && b.pos < *pos {
                        return self.hint_types(Some(caller), &b.hint, depth + 1);
                    }
                }
                self.func(caller).params.get(name).map(|ty| set1(ty.clone()))
            }
            Hint::MCall(base, flds, meth, pos) => {
                let mut cands = if base == "self" && caller.is_some() {
                    self.func(caller.unwrap()).owner.clone().map(set1)
                } else {
                    self.hint_types(
                        caller,
                        &Hint::Var(base.clone(), *pos),
                        depth + 1,
                    )
                };
                for fld in flds {
                    cands = if cands.is_some() {
                        self.fields.get(fld).cloned()
                    } else {
                        None
                    };
                }
                self.method_ret(cands.as_ref(), meth, depth)
            }
            Hint::FreeFn(name) => {
                let mut tys = BTreeSet::new();
                for &c in
                    self.free.get(name).map(Vec::as_slice).unwrap_or(&[]).iter()
                {
                    if let Some(r) = self.ret_of(c) {
                        tys.insert(r);
                    }
                }
                (!tys.is_empty()).then_some(tys)
            }
            Hint::Assoc(ty, meth) => {
                let mut tys = BTreeSet::new();
                for &c in self.method_cands(meth) {
                    if self.func(c).owner.as_deref() == Some(ty.as_str()) {
                        if let Some(r) = self.ret_of(c) {
                            tys.insert(r);
                        }
                    }
                }
                if tys.is_empty() {
                    Some(set1(ty.clone()))
                } else {
                    Some(tys)
                }
            }
            Hint::Unknown => None,
        }
    }

    /// Candidate type names for the receiver ending just before the
    /// `.` at `dot`, or `None` when untypable.
    fn recv_types(
        &self,
        f: &SourceFile,
        dot: usize,
        caller: usize,
    ) -> Option<BTreeSet<String>> {
        let r = dot.checked_sub(1).and_then(|j| f.sig_before(j))?;
        let t = &f.toks[r];
        if t.kind == TokKind::Ident {
            let name = t.text.as_str();
            if name == "self" {
                return self.func(caller).owner.clone().map(set1);
            }
            let prev = r.checked_sub(1).and_then(|j| f.sig_before(j));
            if prev.is_some_and(|p| f.toks[p].is_punct('.')) {
                // `anything.field.meth()` — the field's declared types
                return self.fields.get(name).cloned();
            }
            if name
                .chars()
                .all(|c| c.is_ascii_uppercase() || c.is_ascii_digit() || c == '_')
            {
                return self.statics.get(name).map(|ty| set1(ty.clone()));
            }
            for b in self.bindings[caller].iter().rev() {
                if b.name == name && b.pos < dot {
                    return self.hint_types(Some(caller), &b.hint, 0);
                }
            }
            return self.func(caller).params.get(name).map(|ty| set1(ty.clone()));
        }
        if t.is_punct('}') {
            // `Type { … }.meth()` — struct-literal receiver
            let open = f.match_brace_back(r)?;
            let h = open.checked_sub(1).and_then(|j| f.sig_before(j))?;
            let ht = &f.toks[h];
            if ht.kind == TokKind::Ident
                && ht.text.chars().next().is_some_and(|c| c.is_uppercase())
            {
                return Some(set1(ht.text.clone()));
            }
            return None;
        }
        if t.is_punct(']') {
            // `base[i].meth()` — index into a container: hint from the
            // container's binding/field (the *element* ident is what
            // the field map records for `Vec<T>` fields)
            let open = f.match_bracket_back(r)?;
            let b = open.checked_sub(1).and_then(|j| f.sig_before(j))?;
            let bt = &f.toks[b];
            if bt.kind != TokKind::Ident || bt.is_ident("self") {
                return None;
            }
            let prev2 = b.checked_sub(1).and_then(|j| f.sig_before(j));
            if prev2.is_some_and(|p| f.toks[p].is_punct('.')) {
                return self.fields.get(bt.text.as_str()).cloned();
            }
            return self.hint_types(
                Some(caller),
                &Hint::Var(bt.text.clone(), b),
                1,
            );
        }
        if t.is_punct(')') {
            let open = f.match_paren_back(r)?;
            let m = open.checked_sub(1).and_then(|j| f.sig_before(j))?;
            if f.toks[m].kind != TokKind::Ident {
                return None;
            }
            let pd = m.checked_sub(1).and_then(|j| f.sig_before(j));
            if pd.is_some_and(|p| f.toks[p].is_punct('.')) {
                // `recv.meth(…).method()`: the receiver is the inner
                // call's result — recurse on the inner receiver, then
                // map through `meth`'s return type
                let inner = self.recv_types(f, pd.unwrap(), caller);
                return self.method_ret(inner.as_ref(), &f.toks[m].text, 1);
            }
            if let Some(h) = path_head(f, m) {
                if h.chars().next().is_some_and(|c| c.is_uppercase()) {
                    // `Type::assoc(…).method()`
                    let hint =
                        Hint::Assoc(h.to_string(), f.toks[m].text.clone());
                    return self.hint_types(None, &hint, 0);
                }
            }
            return None;
        }
        None
    }

    /// Methods named `name` compatible with candidate receiver types.
    ///
    /// `None` candidates (or candidates naming an unknown non-std type,
    /// e.g. a generic parameter) keep the conservative
    /// every-same-named-method fan-out; known crate types narrow to
    /// their own impls, and pure std types contribute no edge at all.
    fn narrow_methods(
        &self,
        name: &str,
        cands: Option<&BTreeSet<String>>,
    ) -> Vec<usize> {
        let Some(cands) = cands else { return self.dot_methods(name) };
        let owners = self.trait_owners(cands);
        if owners.iter().any(|o| self.owners.contains(o)) {
            return self
                .dot_methods(name)
                .into_iter()
                .filter(|&c| {
                    self.func(c).owner.as_ref().is_some_and(|o| owners.contains(o))
                })
                .collect();
        }
        if owners
            .iter()
            .all(|c| std_like(c) || self.crate_types.contains(c))
        {
            return Vec::new();
        }
        self.dot_methods(name)
    }

    fn filter_methods(&self, name: &str, keep: impl Fn(&str) -> bool) -> Vec<usize> {
        self.method_cands(name)
            .iter()
            .copied()
            .filter(|&c| self.func(c).owner.as_deref().is_some_and(&keep))
            .collect()
    }

    /// Resolve the called ident at `i` to candidate node indices, per
    /// the module-level resolution strategy.
    fn resolve(&self, f: &SourceFile, i: usize, caller: usize) -> Vec<usize> {
        let name = f.toks[i].text.as_str();
        if KEYWORDS.contains(&name) {
            return Vec::new();
        }
        let prev = i.checked_sub(1).and_then(|j| f.sig_before(j));
        let prev_tok = prev.map(|p| &f.toks[p]);

        // declaration site: `fn name(`
        if prev_tok.is_some_and(|t| t.is_ident("fn")) {
            return Vec::new();
        }
        // method call: `recv.name(`
        if prev_tok.is_some_and(|t| t.is_punct('.')) {
            let cands = self.recv_types(f, prev.unwrap(), caller);
            if cands.is_none()
                && f.sig_at(i + 1).is_some_and(|j| f.toks[j].is_punct(':'))
            {
                // turbofish on an untypable receiver: a std generic
                // method (str::parse, Iterator::sum/collect) — crate
                // methods are monomorphic, so no edge
                return Vec::new();
            }
            return self.narrow_methods(name, cands.as_ref());
        }
        // qualified path: walk `seg::…::name(` backwards
        if prev_tok.is_some_and(|t| t.is_punct(':')) {
            let mut segs: Vec<String> = Vec::new();
            let mut j = prev.unwrap();
            loop {
                // expect `::` then an ident (or `>` from `<T as Tr>::`)
                let Some(c2) = f.sig_before(match j.checked_sub(1) {
                    Some(x) => x,
                    None => break,
                }) else {
                    break;
                };
                if !f.toks[c2].is_punct(':') {
                    break;
                }
                let Some(s) = f.sig_before(match c2.checked_sub(1) {
                    Some(x) => x,
                    None => break,
                }) else {
                    break;
                };
                if f.toks[s].kind != TokKind::Ident {
                    // `<Type as Trait>::name(` — fall back to method fan-out
                    if f.toks[s].is_punct('>') {
                        return self.method_cands(name).to_vec();
                    }
                    break;
                }
                segs.push(f.toks[s].text.clone());
                match s.checked_sub(1).and_then(|x| f.sig_before(x)) {
                    Some(p) if f.toks[p].is_punct(':') => j = p,
                    _ => break,
                }
            }
            segs.reverse();
            let Some(qualifier) = segs.last() else {
                return Vec::new();
            };
            if qualifier == "Self" {
                let owner = self.func(caller).owner.clone();
                return self.filter_methods(name, |o| Some(o) == owner.as_deref());
            }
            if qualifier.chars().next().is_some_and(|c| c.is_ascii_uppercase()) {
                return self.filter_methods(name, |o| o == qualifier);
            }
            // module path: strip crate/self/super qualifiers, suffix-match
            let want: Vec<&String> = segs
                .iter()
                .filter(|s| {
                    !matches!(s.as_str(), "crate" | "self" | "super" | "photonic_dfa")
                })
                .collect();
            let caller_mod = &self.mod_paths[self.nodes[caller].file];
            return self
                .free
                .get(name)
                .map(|cands| {
                    cands
                        .iter()
                        .copied()
                        .filter(|&c| {
                            let m = &self.mod_paths[self.nodes[c].file];
                            if want.is_empty() {
                                return m == caller_mod; // `self::name(`
                            }
                            m.len() >= want.len()
                                && m[m.len() - want.len()..]
                                    .iter()
                                    .zip(&want)
                                    .all(|(a, b)| a == *b)
                        })
                        .collect()
                })
                .unwrap_or_default();
        }
        // bare call: every free fn with this name
        self.free.get(name).cloned().unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn graph_of(sources: &[(&str, &str)]) -> (Vec<SourceFile>, CallGraph) {
        let files: Vec<SourceFile> = sources
            .iter()
            .map(|(p, s)| SourceFile::parse(p, s))
            .collect();
        let g = CallGraph::build(&files);
        (files, g)
    }

    fn idx(g: &CallGraph, qual: &str) -> usize {
        g.nodes.iter().position(|n| n.qual == qual).unwrap()
    }

    #[test]
    fn bare_and_qualified_calls_resolve_by_module() {
        let (files, g) = graph_of(&[
            ("a.rs", "pub fn helper() {} pub fn top() { helper(); }"),
            ("b.rs", "pub fn helper() {} pub fn other() { crate::a::helper(); }"),
        ]);
        let top = idx(&g, "a::top");
        let cl = g.closure(&files, &[top], "hot-path-alloc");
        // bare call fans out to both same-named free fns
        assert!(cl.member[idx(&g, "a::helper")]);
        assert!(cl.member[idx(&g, "b::helper")]);
        // qualified call binds only the matching module
        let other = idx(&g, "b::other");
        let cl2 = g.closure(&files, &[other], "hot-path-alloc");
        assert!(cl2.member[idx(&g, "a::helper")]);
        assert!(!cl2.member[idx(&g, "b::helper")]);
    }

    #[test]
    fn method_calls_fan_out_to_methods_only() {
        let (files, g) = graph_of(&[(
            "m.rs",
            "struct A; impl A { fn go(&self) {} }
             fn go() {}
             fn call(a: &A) { a.go(); }",
        )]);
        let cl = g.closure(&files, &[idx(&g, "m::call")], "x");
        assert!(cl.member[idx(&g, "m::A::go")]);
        assert!(!cl.member[idx(&g, "m::go")]);
    }

    #[test]
    fn typed_receivers_narrow_to_their_impl() {
        // two crate types share a method name; a param-typed receiver
        // binds only its own impl
        let (files, g) = graph_of(&[(
            "m.rs",
            "struct A; struct B;
             impl A { fn go(&self) {} }
             impl B { fn go(&self) {} }
             fn call(a: &A) { a.go(); }",
        )]);
        let cl = g.closure(&files, &[idx(&g, "m::call")], "x");
        assert!(cl.member[idx(&g, "m::A::go")]);
        assert!(!cl.member[idx(&g, "m::B::go")]);
    }

    #[test]
    fn let_bindings_and_fields_type_receivers() {
        let (files, g) = graph_of(&[(
            "m.rs",
            "struct A; struct B;
             struct Holder { item: A }
             impl A { fn go(&self) {} }
             impl B { fn go(&self) {} }
             impl Holder {
                 fn via_field(&self) { self.item.go(); }
                 fn via_let(&self) { let a: A = mk(); a.go(); }
             }
             fn mk() -> A { A }",
        )]);
        for root in ["m::Holder::via_field", "m::Holder::via_let"] {
            let cl = g.closure(&files, &[idx(&g, root)], "x");
            assert!(cl.member[idx(&g, "m::A::go")], "{root}");
            assert!(!cl.member[idx(&g, "m::B::go")], "{root}");
        }
    }

    #[test]
    fn std_only_receivers_add_no_edge() {
        // `v` is a Vec: `.push(…)` must not bind the crate's `push`
        let (files, g) = graph_of(&[(
            "m.rs",
            "struct Stack; impl Stack { fn push(&mut self) {} }
             fn call() { let mut v = vec![1]; v.push(2); }",
        )]);
        let cl = g.closure(&files, &[idx(&g, "m::call")], "x");
        assert!(!cl.member[idx(&g, "m::Stack::push")]);
    }

    #[test]
    fn dot_calls_skip_associated_fns_without_self() {
        // `g.set(…)` on an untypable receiver fans out to self-taking
        // methods only — `Guardish::set` has no receiver
        let (files, g) = graph_of(&[(
            "m.rs",
            "struct Guardish; impl Guardish { fn set(n: usize) {} }
             fn call(g: &G) { g.set(1); }",
        )]);
        let cl = g.closure(&files, &[idx(&g, "m::call")], "x");
        assert!(!cl.member[idx(&g, "m::Guardish::set")]);
    }

    #[test]
    fn std_method_chains_are_opaque() {
        // `.iter().map(…)` — no crate impl defines `iter` dot-callably,
        // so the chain result is std-opaque and `map` binds nothing
        let (files, g) = graph_of(&[(
            "m.rs",
            "struct T; impl T { fn map(&self) {} }
             fn call(xs: &[f32]) { let _s: f32 = xs.iter().map(|x| x).sum(); }",
        )]);
        let cl = g.closure(&files, &[idx(&g, "m::call")], "x");
        assert!(!cl.member[idx(&g, "m::T::map")]);
    }

    #[test]
    fn struct_destructuring_types_bound_names() {
        let (files, g) = graph_of(&[(
            "m.rs",
            "struct A; struct B;
             impl A { fn go(&self) {} }
             impl B { fn go(&self) {} }
             struct S { item: A }
             impl S { fn call(&self) { let Self { item } = self; item.go(); } }",
        )]);
        let cl = g.closure(&files, &[idx(&g, "m::S::call")], "x");
        assert!(cl.member[idx(&g, "m::A::go")]);
        assert!(!cl.member[idx(&g, "m::B::go")]);
    }

    #[test]
    fn closures_attribute_to_enclosing_fn_and_cycles_terminate() {
        let (files, g) = graph_of(&[(
            "m.rs",
            "fn a() { let f = || b(); f(); }
             fn b() { a(); }",
        )]);
        let cl = g.closure(&files, &[idx(&g, "m::a")], "x");
        assert!(cl.member[idx(&g, "m::b")]);
        assert_eq!(cl.trail(idx(&g, "m::b")), vec![idx(&g, "m::a"), idx(&g, "m::b")]);
    }

    #[test]
    fn lock_sites_and_order_edges() {
        let (files, g) = graph_of(&[(
            "m.rs",
            "struct S; impl S {
                 fn ab(&self) { let a = self.m1.lock(); let b = self.m2.lock(); }
                 fn ba(&self) { let b = self.m2.lock(); let a = self.m1.lock(); }
             }",
        )]);
        let mut debt = 0;
        let edges = g.order_edges(&files, &mut debt);
        let pairs: Vec<(&str, &str)> =
            edges.iter().map(|e| (e.a.as_str(), e.b.as_str())).collect();
        assert!(pairs.contains(&("S.m1", "S.m2")));
        assert!(pairs.contains(&("S.m2", "S.m1")));
        assert_eq!(debt, 0);
    }

    #[test]
    fn callee_locks_order_after_held_locks() {
        let (files, g) = graph_of(&[(
            "m.rs",
            "fn inner_lock(q: &Q) { q.mx.lock(); }
             fn outer(s: &S, q: &Q) { s.other.lock(); inner_lock(q); }",
        )]);
        let sets = g.lock_sets();
        let outer = idx(&g, "m::outer");
        assert!(sets[outer].contains("m.mx"));
        assert!(sets[outer].contains("m.other"));
        let mut debt = 0;
        let edges = g.order_edges(&files, &mut debt);
        assert!(edges.iter().any(|e| e.a == "m.other" && e.b == "m.mx"));
    }

    #[test]
    fn lock_and_release_helpers_do_not_leak_into_callers() {
        // `helper` locks and releases (no guard in its return type), so
        // after the call the caller holds nothing: acquiring `m2` next
        // must NOT create a `m.m1 -> m.m2` edge through the call.
        let (files, g) = graph_of(&[(
            "m.rs",
            "fn helper(q: &Q) { q.m1.lock(); }
             fn caller(q: &Q) { helper(q); q.m2.lock(); }",
        )]);
        let mut debt = 0;
        let edges = g.order_edges(&files, &mut debt);
        assert!(!edges.iter().any(|e| e.a == "m.m1" && e.b == "m.m2"), "{edges:?}");
    }

    #[test]
    fn guard_returning_callees_extend_the_held_set() {
        let (files, g) = graph_of(&[(
            "m.rs",
            "fn acquire(q: &Q) -> QGuard<'_> { q.m1.lock() }
             fn caller(q: &Q) { let g = acquire(q); q.m2.lock(); }",
        )]);
        let mut debt = 0;
        let edges = g.order_edges(&files, &mut debt);
        assert!(edges.iter().any(|e| e.a == "m.m1" && e.b == "m.m2"), "{edges:?}");
    }

    #[test]
    fn test_mod_fns_are_not_nodes() {
        let (_, g) = graph_of(&[(
            "m.rs",
            "fn live() {}
             #[cfg(test)]
             mod tests { fn live() {} }",
        )]);
        assert_eq!(g.nodes.len(), 1);
    }
}
