//! In-repo static analysis: `pdfa lint`.
//!
//! A hermetic (zero-dependency, no `syn`) lexical analyzer that walks
//! `rust/src/**` and enforces the repo's cross-cutting contracts as
//! named, individually-suppressable rules — hot-path allocation
//! freedom, keyed-RNG determinism, scoped thread-cap mutation,
//! panic-free serve threads, wallclock containment, atomic-ordering
//! justification, determinism taint and lock ordering. Runtime tests
//! sample a handful of code paths; this pass checks every call site at
//! CI time. See DESIGN.md ("Static analysis") for the rule catalogue
//! and pragma vocabulary.
//!
//! Pipeline: [`lexer`] turns a source file into a line-tagged token
//! stream (comments retained — they carry the pragmas), [`ast`] scopes
//! items/function bodies and attaches pragmas, [`graph`] builds the
//! crate-wide call graph, [`rules`] walks files and reachability
//! closures and emits [`Diag`]s. [`lint_repo`] drives the full walk
//! (source tree plus `benches/`/`tests/` under the relaxed subset);
//! [`lint_source`]/[`lint_sources`] are the fixture-test entry points.

pub mod ast;
pub mod graph;
pub mod lexer;
pub mod rules;

use std::fs;
use std::path::{Path, PathBuf};

use crate::util::json::Value;
use crate::{Error, Result};

pub use ast::SourceFile;
pub use graph::CallGraph;
pub use rules::{Diag, RULES};

/// Call-graph shape + per-rule transitive root sets, as recorded in
/// `LINT.json`.
#[derive(Debug, Default)]
pub struct GraphSummary {
    pub nodes: usize,
    pub edges: usize,
    /// Rule name → sorted root display names (for lock-order: the
    /// mutex identities the graph observed).
    pub roots: Vec<(&'static str, Vec<String>)>,
}

/// Outcome of linting a whole tree: where we looked, how many files we
/// parsed, every finding (sorted by file, then line, then rule), the
/// graph summary, and the suppression debt spent keeping the findings
/// list empty.
#[derive(Debug)]
pub struct LintReport {
    pub root: String,
    pub files: usize,
    pub findings: Vec<Diag>,
    pub graph: GraphSummary,
    /// Per-rule count of suppressions that fired (allow/boundary
    /// contracts). CI caps this against the committed baseline — debt
    /// may shrink, never grow.
    pub debt: rules::Debt,
    /// DOT rendering of the hot-path closure (`pdfa lint --graph`).
    pub hot_path_dot: String,
}

impl LintReport {
    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// JSON shape consumed by CI (`.github/workflows/ci.yml` asserts
    /// `lint == "pdfa"`, `files > 0`, eight rules, empty findings,
    /// well-formed `graph` + `suppressed` maps).
    pub fn to_value(&self) -> Value {
        Value::object(vec![
            ("lint", Value::String("pdfa".to_string())),
            ("root", Value::String(self.root.clone())),
            ("files", Value::Number(self.files as f64)),
            (
                "rules",
                Value::Array(
                    RULES
                        .iter()
                        .map(|r| Value::String(r.to_string()))
                        .collect(),
                ),
            ),
            (
                "findings",
                Value::Array(
                    self.findings
                        .iter()
                        .map(|d| {
                            Value::object(vec![
                                ("file", Value::String(d.file.clone())),
                                ("line", Value::Number(d.line as f64)),
                                ("rule", Value::String(d.rule.to_string())),
                                ("message", Value::String(d.msg.clone())),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "graph",
                Value::object(vec![
                    ("nodes", Value::Number(self.graph.nodes as f64)),
                    ("edges", Value::Number(self.graph.edges as f64)),
                    (
                        "roots",
                        Value::object(
                            self.graph
                                .roots
                                .iter()
                                .map(|(rule, names)| {
                                    (
                                        *rule,
                                        Value::Array(
                                            names
                                                .iter()
                                                .map(|n| Value::String(n.clone()))
                                                .collect(),
                                        ),
                                    )
                                })
                                .collect(),
                        ),
                    ),
                ]),
            ),
            (
                "suppressed",
                Value::object(
                    self.debt
                        .iter()
                        .map(|(rule, n)| (*rule, Value::Number(*n as f64)))
                        .collect(),
                ),
            ),
        ])
    }

    /// Human-readable `file:line: rule: message` lines.
    pub fn render(&self) -> String {
        let mut s = String::new();
        for d in &self.findings {
            s.push_str(&format!("{}:{}: {}: {}\n", d.file, d.line, d.rule, d.msg));
        }
        s
    }
}

/// Compare this run's suppression debt against a previously committed
/// `LINT.json`: per rule, debt may only shrink or hold. Contracts are
/// paid down, never silently accumulated.
pub fn check_baseline(report: &LintReport, baseline: &Value) -> Result<()> {
    let Some(base) = baseline.get("suppressed").as_object() else {
        return Err(Error::Manifest(
            "lint baseline: no `suppressed` map (regenerate LINT.json)".to_string(),
        ));
    };
    let mut over = Vec::new();
    for (rule, n) in &report.debt {
        let cap = base.get(*rule).and_then(|v| v.as_usize()).unwrap_or(0);
        if *n > cap {
            over.push(format!("{rule}: {n} suppression(s) > baseline {cap}"));
        }
    }
    if over.is_empty() {
        Ok(())
    } else {
        Err(Error::Manifest(format!(
            "lint suppression debt above committed baseline — pay one down or \
             update LINT.json deliberately: {}",
            over.join("; ")
        )))
    }
}

/// The full crate pass over already-parsed files.
fn analyze(files: Vec<SourceFile>, root: String, extra_files: usize) -> LintReport {
    let g = CallGraph::build(&files);
    let mut findings = Vec::new();
    let mut debt = rules::new_debt();
    rules::check_crate(&files, &g, &mut findings, &mut debt);
    let mut roots = rules::rule_roots(&files, &g);
    for (_, names) in &mut roots {
        names.sort();
    }
    let hot_path_dot = hot_path_dot(&files, &g);
    sort_findings(&mut findings);
    LintReport {
        root,
        files: files.len() + extra_files,
        findings,
        graph: GraphSummary { nodes: g.nodes.len(), edges: g.edge_count, roots },
        debt,
        hot_path_dot,
    }
}

/// DOT rendering of the hot-path closure: member fns as nodes (roots
/// boxed), member-to-member call edges.
fn hot_path_dot(files: &[SourceFile], g: &CallGraph) -> String {
    let roots: Vec<usize> = g
        .nodes
        .iter()
        .enumerate()
        .filter(|(_, n)| files[n.file].fns[n.func].has_pragma("hot-path"))
        .map(|(i, _)| i)
        .collect();
    let cl = g.closure(files, &roots, rules::HOT_PATH_ALLOC);
    let mut s = String::from("digraph hot_path_closure {\n");
    s.push_str("  rankdir=LR;\n  node [fontname=\"monospace\"];\n");
    for (ni, node) in g.nodes.iter().enumerate() {
        if !cl.member[ni] {
            continue;
        }
        let shape = if roots.contains(&ni) { " [shape=box]" } else { "" };
        s.push_str(&format!("  \"{}\"{shape};\n", node.qual));
    }
    let mut edges = std::collections::BTreeSet::new();
    for (ni, evs) in g.events.iter().enumerate() {
        if !cl.member[ni] {
            continue;
        }
        for ev in evs {
            if let graph::Event::Call { callee, .. } = ev {
                if cl.member[*callee] {
                    edges.insert((
                        g.nodes[ni].qual.clone(),
                        g.nodes[*callee].qual.clone(),
                    ));
                }
            }
        }
    }
    for (a, b) in edges {
        s.push_str(&format!("  \"{a}\" -> \"{b}\";\n"));
    }
    s.push_str("}\n");
    s
}

fn sort_findings(findings: &mut [Diag]) {
    findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
}

/// Lint a single source text under a display path (fixture-test entry
/// point; the call graph covers just this file).
pub fn lint_source(path: &str, src: &str) -> Vec<Diag> {
    lint_sources(&[(path, src)])
}

/// Lint several in-memory sources as one crate — fixtures exercising
/// cross-module call-graph resolution use this.
pub fn lint_sources(sources: &[(&str, &str)]) -> Vec<Diag> {
    let files: Vec<SourceFile> = sources
        .iter()
        .map(|(p, s)| SourceFile::parse(p, s))
        .collect();
    analyze(files, "<memory>".to_string(), 0).findings
}

/// Recursively lint every `.rs` file under `root` as one crate, in
/// sorted order so reports are deterministic across filesystems.
pub fn lint_tree(root: &Path) -> Result<LintReport> {
    let mut paths = Vec::new();
    collect_rs(root, &mut paths)?;
    paths.sort();
    let mut files = Vec::new();
    for path in &paths {
        let src = fs::read_to_string(path).map_err(|e| {
            Error::Manifest(format!("lint: read {}: {e}", path.display()))
        })?;
        // report paths relative to the lint root, with forward slashes
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        files.push(SourceFile::parse(&rel, &src));
    }
    Ok(analyze(files, root.to_string_lossy().into_owned(), 0))
}

/// [`lint_tree`] over the source root, plus the sibling `benches/` and
/// `tests/` trees under the relaxed rule subset (no-raw-thread-cap and
/// no-wallclock-in-determinism): bench/test code may allocate and
/// panic, but must not reintroduce raw `set_thread_cap` calls or
/// unsanctioned wallclock reads.
pub fn lint_repo(src_root: &Path) -> Result<LintReport> {
    let mut report = lint_tree(src_root)?;
    for anc in [src_root.parent(), src_root.parent().and_then(|p| p.parent())]
        .into_iter()
        .flatten()
    {
        let aux: Vec<PathBuf> = ["benches", "tests"]
            .iter()
            .map(|d| anc.join(d))
            .filter(|p| p.is_dir())
            .collect();
        if aux.is_empty() {
            continue;
        }
        for dir in aux {
            let mut paths = Vec::new();
            collect_rs(&dir, &mut paths)?;
            paths.sort();
            for path in &paths {
                let src = fs::read_to_string(path).map_err(|e| {
                    Error::Manifest(format!("lint: read {}: {e}", path.display()))
                })?;
                let rel = path
                    .strip_prefix(anc)
                    .unwrap_or(path)
                    .to_string_lossy()
                    .replace('\\', "/");
                let f = SourceFile::parse(&rel, &src);
                rules::check_file_relaxed(&f, &mut report.findings, &mut report.debt);
                report.files += 1;
            }
        }
        break; // nearest ancestor with aux trees wins
    }
    sort_findings(&mut report.findings);
    Ok(report)
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let entries = fs::read_dir(dir).map_err(|e| {
        Error::Manifest(format!("lint: read dir {}: {e}", dir.display()))
    })?;
    for entry in entries {
        let entry = entry
            .map_err(|e| Error::Manifest(format!("lint: walk {}: {e}", dir.display())))?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|x| x == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_json_shape() {
        let rep = LintReport {
            root: "rust/src".to_string(),
            files: 3,
            findings: vec![Diag {
                file: "a.rs".to_string(),
                line: 7,
                rule: rules::HOT_PATH_ALLOC,
                msg: "boom".to_string(),
            }],
            graph: GraphSummary {
                nodes: 10,
                edges: 4,
                roots: vec![(rules::HOT_PATH_ALLOC, vec!["a::hot".to_string()])],
            },
            debt: rules::new_debt(),
            hot_path_dot: String::new(),
        };
        let v = rep.to_value();
        assert_eq!(v.get("lint").as_str(), Some("pdfa"));
        assert_eq!(v.get("files").as_usize(), Some(3));
        assert_eq!(v.get("rules").as_array().map(|a| a.len()), Some(8));
        let f = &v.get("findings").as_array().unwrap()[0];
        assert_eq!(f.get("rule").as_str(), Some("hot-path-alloc"));
        assert_eq!(f.get("line").as_usize(), Some(7));
        assert_eq!(v.get("graph").get("nodes").as_usize(), Some(10));
        assert_eq!(v.get("graph").get("edges").as_usize(), Some(4));
        let roots = v.get("graph").get("roots").get("hot-path-alloc");
        assert_eq!(roots.as_array().map(|a| a.len()), Some(1));
        let sup = v.get("suppressed").as_object().unwrap();
        assert_eq!(sup.len(), RULES.len());
        assert!(rep.render().contains("a.rs:7: hot-path-alloc: boom"));
    }

    #[test]
    fn lint_source_finds_and_suppresses() {
        let bad = r#"
// lint: hot-path
fn hot(xs: &[f32]) -> Vec<f32> { xs.to_vec() }
"#;
        let diags = lint_source("fixture.rs", bad);
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].rule, rules::HOT_PATH_ALLOC);

        let ok = r#"
// lint: hot-path
// lint: allow(hot-path-alloc) — scratch reuse lands in the next pass
fn hot(xs: &[f32]) -> Vec<f32> { xs.to_vec() }
"#;
        assert!(lint_source("fixture.rs", ok).is_empty());
    }

    #[test]
    fn transitive_findings_name_the_path() {
        let src = r#"
// lint: hot-path
fn root() { helper(); }
fn helper() { let v = vec![1]; }
"#;
        let diags = lint_source("fixture.rs", src);
        assert_eq!(diags.len(), 1);
        assert!(
            diags[0].msg.contains("reachable from `fixture::root`"),
            "{}",
            diags[0].msg
        );
    }

    #[test]
    fn baseline_caps_suppression_debt() {
        let mut rep = LintReport {
            root: String::new(),
            files: 1,
            findings: Vec::new(),
            graph: GraphSummary::default(),
            debt: rules::new_debt(),
            hot_path_dot: String::new(),
        };
        rep.debt.insert(rules::HOT_PATH_ALLOC, 2);
        let base = rep.to_value();
        assert!(check_baseline(&rep, &base).is_ok());
        rep.debt.insert(rules::HOT_PATH_ALLOC, 3);
        assert!(check_baseline(&rep, &base).is_err());
        rep.debt.insert(rules::HOT_PATH_ALLOC, 1);
        assert!(check_baseline(&rep, &base).is_ok());
    }

    #[test]
    fn dot_contains_closure_edges() {
        let files = vec![SourceFile::parse(
            "m.rs",
            "// lint: hot-path\nfn root() { helper(); }\nfn helper() {}",
        )];
        let g = CallGraph::build(&files);
        let dot = hot_path_dot(&files, &g);
        assert!(dot.contains("\"m::root\" [shape=box]"), "{dot}");
        assert!(dot.contains("\"m::root\" -> \"m::helper\""), "{dot}");
    }
}
