//! In-repo static analysis: `pdfa lint`.
//!
//! A hermetic (zero-dependency, no `syn`) lexical analyzer that walks
//! `rust/src/**` and enforces the repo's cross-cutting contracts as
//! named, individually-suppressable rules — hot-path allocation
//! freedom, keyed-RNG determinism, scoped thread-cap mutation,
//! panic-free serve threads, wallclock containment and atomic-ordering
//! justification. Runtime tests sample a handful of code paths; this
//! pass checks every call site at CI time. See DESIGN.md ("Static
//! analysis") for the rule catalogue and pragma vocabulary.
//!
//! Pipeline: [`lexer`] turns a source file into a line-tagged token
//! stream (comments retained — they carry the pragmas), [`ast`] scopes
//! items/function bodies and attaches pragmas, [`rules`] walks the
//! result and emits [`Diag`]s. [`lint_tree`] drives the walk;
//! [`lint_source`] is the fixture-test entry point.

pub mod ast;
pub mod lexer;
pub mod rules;

use std::fs;
use std::path::{Path, PathBuf};

use crate::util::json::Value;
use crate::{Error, Result};

pub use ast::SourceFile;
pub use rules::{Diag, RULES};

/// Outcome of linting a whole tree: where we looked, how many files we
/// parsed, and every finding (sorted by file, then line, then rule).
#[derive(Debug)]
pub struct LintReport {
    pub root: String,
    pub files: usize,
    pub findings: Vec<Diag>,
}

impl LintReport {
    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// JSON shape consumed by CI (`.github/workflows/ci.yml` asserts
    /// `lint == "pdfa"`, `files > 0`, six rules, empty findings).
    pub fn to_value(&self) -> Value {
        Value::object(vec![
            ("lint", Value::String("pdfa".to_string())),
            ("root", Value::String(self.root.clone())),
            ("files", Value::Number(self.files as f64)),
            (
                "rules",
                Value::Array(
                    RULES
                        .iter()
                        .map(|r| Value::String(r.to_string()))
                        .collect(),
                ),
            ),
            (
                "findings",
                Value::Array(
                    self.findings
                        .iter()
                        .map(|d| {
                            Value::object(vec![
                                ("file", Value::String(d.file.clone())),
                                ("line", Value::Number(d.line as f64)),
                                ("rule", Value::String(d.rule.to_string())),
                                ("message", Value::String(d.msg.clone())),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Human-readable `file:line: rule: message` lines.
    pub fn render(&self) -> String {
        let mut s = String::new();
        for d in &self.findings {
            s.push_str(&format!("{}:{}: {}: {}\n", d.file, d.line, d.rule, d.msg));
        }
        s
    }
}

/// Lint a single source text under a display path. Used by the fixture
/// tests and by [`lint_tree`] per file.
pub fn lint_source(path: &str, src: &str) -> Vec<Diag> {
    let f = SourceFile::parse(path, src);
    let mut out = Vec::new();
    rules::check_file(&f, &mut out);
    out
}

/// Recursively lint every `.rs` file under `root`, in sorted order so
/// reports are deterministic across filesystems.
pub fn lint_tree(root: &Path) -> Result<LintReport> {
    let mut files = Vec::new();
    collect_rs(root, &mut files)?;
    files.sort();
    let mut findings = Vec::new();
    for path in &files {
        let src = fs::read_to_string(path).map_err(|e| {
            Error::Manifest(format!("lint: read {}: {e}", path.display()))
        })?;
        // report paths relative to the lint root, with forward slashes
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        findings.extend(lint_source(&rel, &src));
    }
    findings.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule).cmp(&(b.file.as_str(), b.line, b.rule))
    });
    Ok(LintReport {
        root: root.to_string_lossy().into_owned(),
        files: files.len(),
        findings,
    })
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let entries = fs::read_dir(dir).map_err(|e| {
        Error::Manifest(format!("lint: read dir {}: {e}", dir.display()))
    })?;
    for entry in entries {
        let entry = entry
            .map_err(|e| Error::Manifest(format!("lint: walk {}: {e}", dir.display())))?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|x| x == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_json_shape() {
        let rep = LintReport {
            root: "rust/src".to_string(),
            files: 3,
            findings: vec![Diag {
                file: "a.rs".to_string(),
                line: 7,
                rule: rules::HOT_PATH_ALLOC,
                msg: "boom".to_string(),
            }],
        };
        let v = rep.to_value();
        assert_eq!(v.get("lint").as_str(), Some("pdfa"));
        assert_eq!(v.get("files").as_usize(), Some(3));
        assert_eq!(v.get("rules").as_array().map(|a| a.len()), Some(6));
        let f = &v.get("findings").as_array().unwrap()[0];
        assert_eq!(f.get("rule").as_str(), Some("hot-path-alloc"));
        assert_eq!(f.get("line").as_usize(), Some(7));
        assert!(rep.render().contains("a.rs:7: hot-path-alloc: boom"));
    }

    #[test]
    fn lint_source_finds_and_suppresses() {
        let bad = r#"
// lint: hot-path
fn hot(xs: &[f32]) -> Vec<f32> { xs.to_vec() }
"#;
        let diags = lint_source("fixture.rs", bad);
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].rule, rules::HOT_PATH_ALLOC);

        let ok = r#"
// lint: hot-path
// lint: allow(hot-path-alloc)
fn hot(xs: &[f32]) -> Vec<f32> { xs.to_vec() }
"#;
        assert!(lint_source("fixture.rs", ok).is_empty());
    }
}
