//! The eight repo-invariant lint rules.
//!
//! Each rule is a named, individually-suppressable check (see
//! DESIGN.md, "Static analysis", for the invariant each one guards).
//! Four rules are *fn-local* token scans; four are *transitive* — they
//! walk the [`CallGraph`] closure from annotated roots so an
//! un-annotated helper three calls down is held to the same contract
//! as the root. Findings inside `#[cfg(test)]` modules are skipped
//! wholesale — test code may allocate, panic and read the clock
//! freely.
//!
//! Suppression is explicit, local and *written*: a fn-level
//! `// lint: allow(<rule>) — why`, a line-level pragma (`allow`,
//! `timing`, `ordering`, `guarded`), or a fn-level
//! `// lint: boundary(<rule>) — why` that stops a closure's descent.
//! An `allow`/`boundary` without a contract note suppresses nothing.
//! Every suppression that fires is tallied into the per-rule
//! suppression-debt map that `LINT.json` carries and CI caps against
//! the committed baseline.

use std::collections::BTreeMap;

use super::ast::{Function, SourceFile};
use super::graph::{CallGraph, Closure};
use super::lexer::TokKind;

/// One finding: file, line, rule name and a human-readable message.
#[derive(Debug, Clone, PartialEq)]
pub struct Diag {
    pub file: String,
    pub line: u32,
    pub rule: &'static str,
    pub msg: String,
}

/// Every rule name, in the order they run. Fixture tests assert each
/// one fires; `pdfa lint --json` records the list in the report.
pub const RULES: [&str; 8] = [
    HOT_PATH_ALLOC,
    NO_RAW_THREAD_CAP,
    KEYED_RNG_ONLY,
    PANIC_FREE_SERVE,
    NO_WALLCLOCK,
    ATOMIC_ORDERING,
    DETERMINISM_TAINT,
    LOCK_ORDER,
];

pub const HOT_PATH_ALLOC: &str = "hot-path-alloc";
pub const NO_RAW_THREAD_CAP: &str = "no-raw-thread-cap";
pub const KEYED_RNG_ONLY: &str = "keyed-rng-only";
pub const PANIC_FREE_SERVE: &str = "panic-free-serve";
pub const NO_WALLCLOCK: &str = "no-wallclock-in-determinism";
pub const ATOMIC_ORDERING: &str = "atomic-ordering-audit";
pub const DETERMINISM_TAINT: &str = "determinism-taint";
pub const LOCK_ORDER: &str = "lock-order";

/// Fn names that root the determinism-taint closure: the photonic
/// dispatch entry points whose results must be bit-identical at any
/// `--threads` (PR 4's contract).
pub const DETERMINISM_ROOTS: [&str; 3] =
    ["bank_linear", "bank_dfa_gradient", "eval_into"];

/// Allocating method/associated-fn idents banned in hot-path closures.
const ALLOC_CALLS: [&str; 4] = ["clone", "to_vec", "collect", "with_capacity"];
/// Allocating macros banned in hot-path closures.
const ALLOC_MACROS: [&str; 2] = ["format", "vec"];
/// Panicking macros banned in serve-thread closures.
const PANIC_MACROS: [&str; 4] = ["panic", "unreachable", "todo", "unimplemented"];
/// Non-`keyed` `Pcg64` constructors banned in determinism closures.
const RNG_CTORS: [&str; 4] = ["new", "seed", "fork", "from_state_bytes"];
/// Atomic orderings stricter than `Relaxed` (the cmp::Ordering variants
/// Less/Equal/Greater never collide with these names).
const STRICT_ORDERINGS: [&str; 4] = ["Acquire", "Release", "AcqRel", "SeqCst"];
/// Keywords that may directly precede `[` without forming an index
/// expression (`for x in [..]`, `return [..]`, …).
const NON_INDEX_KEYWORDS: [&str; 10] = [
    "in", "return", "break", "if", "else", "match", "let", "mut", "ref", "box",
];

/// Per-rule count of suppressions that actually fired (allow pragmas
/// that swallowed a finding, pruned call edges, boundary stops).
pub type Debt = BTreeMap<&'static str, usize>;

pub fn new_debt() -> Debt {
    RULES.iter().map(|r| (*r, 0usize)).collect()
}

fn spend(debt: &mut Debt, rule: &'static str, n: usize) {
    *debt.entry(rule).or_insert(0) += n;
}

/// Run the whole-crate pass: fn-local rules per file, then the four
/// transitive rules over `graph`.
pub fn check_crate(
    files: &[SourceFile],
    graph: &CallGraph,
    out: &mut Vec<Diag>,
    debt: &mut Debt,
) {
    for f in files {
        no_raw_thread_cap(f, out, debt);
        keyed_rng_only(f, out, debt);
        no_wallclock(f, out, debt);
        atomic_ordering(f, out, debt);
    }
    hot_path_alloc(files, graph, out, debt);
    panic_free_serve(files, graph, out, debt);
    determinism_taint(files, graph, out, debt);
    lock_order(files, graph, out, debt);
}

/// The relaxed subset for `benches/` and `tests/`: bench/test code may
/// allocate, panic and lock freely, but must not reintroduce raw
/// thread-cap mutation or unsanctioned wallclock reads.
pub fn check_file_relaxed(f: &SourceFile, out: &mut Vec<Diag>, debt: &mut Debt) {
    no_raw_thread_cap(f, out, debt);
    no_wallclock(f, out, debt);
}

/// Per-rule transitive root sets for the `LINT.json` graph summary.
/// Lock-order's "roots" are the mutexes the graph observed.
pub fn rule_roots(
    files: &[SourceFile],
    graph: &CallGraph,
) -> Vec<(&'static str, Vec<String>)> {
    let quals = |pred: &dyn Fn(&Function) -> bool| -> Vec<String> {
        graph
            .nodes
            .iter()
            .filter(|n| pred(&files[n.file].fns[n.func]))
            .map(|n| n.qual.clone())
            .collect()
    };
    vec![
        (HOT_PATH_ALLOC, quals(&|f| f.has_pragma("hot-path"))),
        (PANIC_FREE_SERVE, quals(&|f| f.has_pragma("thread-body"))),
        (
            DETERMINISM_TAINT,
            quals(&|f| DETERMINISM_ROOTS.contains(&f.name.as_str())),
        ),
        (LOCK_ORDER, graph.mutexes().into_iter().collect()),
    ]
}

/// Shared finding sink: drops the diag (and tallies the debt) if the
/// token is in test code or a written fn/line-level suppression covers
/// it.
fn emit(
    f: &SourceFile,
    out: &mut Vec<Diag>,
    debt: &mut Debt,
    idx: usize,
    fnc: Option<&Function>,
    rule: &'static str,
    msg: String,
) {
    if f.in_test(idx) {
        return;
    }
    emit_at_line(f, out, debt, f.toks[idx].line, fnc, rule, msg);
}

fn emit_at_line(
    f: &SourceFile,
    out: &mut Vec<Diag>,
    debt: &mut Debt,
    line: u32,
    fnc: Option<&Function>,
    rule: &'static str,
    msg: String,
) {
    if fnc.is_some_and(|func| func.allows(rule)) {
        spend(debt, rule, 1);
        return;
    }
    if f.line_pragma(line, "allow")
        .is_some_and(|p| p.arg == rule && !p.note.is_empty())
    {
        spend(debt, rule, 1);
        return;
    }
    out.push(Diag { file: f.path.clone(), line, rule, msg });
}

/// Is the ident at `i` called (next significant token `(`), possibly
/// through a turbofish/path (`::`)?
fn is_call(f: &SourceFile, i: usize) -> bool {
    match f.sig_at(i + 1) {
        Some(j) => f.toks[j].is_punct('(') || f.toks[j].is_punct(':'),
        None => false,
    }
}

/// The path head two significant tokens back, if `i` is reached via
/// `Head::ident` (returns the text of `Head`).
fn path_head<'a>(f: &'a SourceFile, i: usize) -> Option<&'a str> {
    let c1 = f.sig_before(i.checked_sub(1)?)?;
    if !f.toks[c1].is_punct(':') {
        return None;
    }
    let c2 = f.sig_before(c1.checked_sub(1)?)?;
    if !f.toks[c2].is_punct(':') {
        return None;
    }
    let h = f.sig_before(c2.checked_sub(1)?)?;
    (f.toks[h].kind == TokKind::Ident).then(|| f.toks[h].text.as_str())
}

/// "reachable from `root` via `a` → `b`" suffix for transitive
/// findings (empty for findings in the root itself).
fn via(graph: &CallGraph, cl: &Closure, ni: usize) -> String {
    let chain = cl.trail(ni);
    if chain.len() < 2 {
        return String::new();
    }
    let names: Vec<&str> =
        chain.iter().map(|&x| graph.nodes[x].qual.as_str()).collect();
    format!(
        " (reachable from `{}` via {})",
        names[0],
        names[1..]
            .iter()
            .map(|n| format!("`{n}`"))
            .collect::<Vec<_>>()
            .join(" → ")
    )
}

/// Walk every member of `cl`, calling `scan` with the member's node
/// index, file, fn and the token indices attributed to it (innermost
/// enclosing fn wins, so nested fns are visited once, as themselves).
fn for_member_tokens(
    files: &[SourceFile],
    graph: &CallGraph,
    cl: &Closure,
    mut scan: impl FnMut(usize, &SourceFile, &Function, usize),
) {
    for (ni, node) in graph.nodes.iter().enumerate() {
        if !cl.member[ni] {
            continue;
        }
        let f = &files[node.file];
        let func = &f.fns[node.func];
        for i in func.body.0..func.body.1 {
            if graph.node_at(node.file, i) == Some(ni) {
                scan(ni, f, func, i);
            }
        }
    }
}

/// Collect node indices by fn predicate (closure roots).
fn roots_where(
    files: &[SourceFile],
    graph: &CallGraph,
    pred: impl Fn(&Function) -> bool,
) -> Vec<usize> {
    graph
        .nodes
        .iter()
        .enumerate()
        .filter(|(_, n)| pred(&files[n.file].fns[n.func]))
        .map(|(i, _)| i)
        .collect()
}

/// **hot-path-alloc** (transitive) — no allocating calls or macros
/// anywhere in the closure of functions marked `// lint: hot-path`:
/// `clone()`, `to_vec()`, `collect()`, `with_capacity()`, `Vec::new()`,
/// `Box::new()`, `String::from()`, `format!`, `vec!`. The steady-state
/// serve and photonic dispatch paths are allocation-free by contract
/// (`tests/alloc_*.rs` sample them at runtime; this rule checks every
/// call site statically, including helpers the roots reach).
fn hot_path_alloc(
    files: &[SourceFile],
    graph: &CallGraph,
    out: &mut Vec<Diag>,
    debt: &mut Debt,
) {
    let roots = roots_where(files, graph, |x| x.has_pragma("hot-path"));
    let cl = graph.closure(files, &roots, HOT_PATH_ALLOC);
    spend(debt, HOT_PATH_ALLOC, cl.boundaries.len() + cl.pruned.len());
    for_member_tokens(files, graph, &cl, |ni, f, func, i| {
        let t = &f.toks[i];
        if t.kind != TokKind::Ident {
            return;
        }
        let name = t.text.as_str();
        let flagged = if ALLOC_CALLS.contains(&name) && is_call(f, i) {
            Some(name.to_string())
        } else if ALLOC_MACROS.contains(&name)
            && f.sig_at(i + 1).is_some_and(|j| f.toks[j].is_punct('!'))
        {
            Some(format!("{name}!"))
        } else if name == "new" && is_call(f, i) {
            match path_head(f, i) {
                Some(h @ ("Vec" | "Box")) => Some(format!("{h}::new")),
                _ => None,
            }
        } else if name == "from" && is_call(f, i) && path_head(f, i) == Some("String")
        {
            Some("String::from".to_string())
        } else {
            None
        };
        if let Some(what) = flagged {
            let suffix = via(graph, &cl, ni);
            let msg = if suffix.is_empty() {
                format!("`{what}` allocates inside hot-path fn `{}`", func.name)
            } else {
                format!("`{what}` allocates in `{}`{suffix}", func.name)
            };
            emit(f, out, debt, i, Some(func), HOT_PATH_ALLOC, msg);
        }
    });
}

/// **no-raw-thread-cap** — `ops::set_thread_cap` is callable only from
/// `ThreadCapGuard` (its defining module, `tensor/ops.rs`, is exempt).
/// Raw calls from concurrently running scopes race on the process
/// global and leak their override; scoped guards serialize and restore.
fn no_raw_thread_cap(f: &SourceFile, out: &mut Vec<Diag>, debt: &mut Debt) {
    if f.path.ends_with("tensor/ops.rs") {
        return;
    }
    for i in 0..f.toks.len() {
        let t = &f.toks[i];
        if !t.is_ident("set_thread_cap") {
            continue;
        }
        // skip the declaration itself and `use` imports (no call parens)
        if f.sig_before(i.saturating_sub(1)).is_some_and(|j| f.toks[j].is_ident("fn")) {
            continue;
        }
        if !f.sig_at(i + 1).is_some_and(|j| f.toks[j].is_punct('(')) {
            continue;
        }
        let fnc = f.enclosing_fn(i);
        emit(
            f,
            out,
            debt,
            i,
            fnc,
            NO_RAW_THREAD_CAP,
            "raw `set_thread_cap` call outside `ThreadCapGuard`; use a \
             scoped guard (or `// lint: allow(no-raw-thread-cap)` with a \
             written contract)"
                .to_string(),
        );
    }
}

/// **keyed-rng-only** — inside row-parallel eval regions (functions
/// marked `// lint: rng-region`) RNGs may only be built with
/// `Pcg64::keyed(seed, op, lane)`: sequentially-seeded streams make
/// results depend on which worker ran which row, breaking the
/// bit-identical-at-any-`--threads` contract the photonic results
/// depend on. (The determinism-taint rule extends this transitively
/// from the dispatch roots.)
fn keyed_rng_only(f: &SourceFile, out: &mut Vec<Diag>, debt: &mut Debt) {
    for func in f.fns.iter().filter(|x| x.has_pragma("rng-region")) {
        for i in func.body.0..func.body.1 {
            let t = &f.toks[i];
            if t.kind != TokKind::Ident {
                continue;
            }
            let banned = RNG_CTORS.contains(&t.text.as_str());
            if banned && path_head(f, i) == Some("Pcg64") && is_call(f, i) {
                emit(
                    f,
                    out,
                    debt,
                    i,
                    Some(func),
                    KEYED_RNG_ONLY,
                    format!(
                        "`Pcg64::{}` inside rng-region fn `{}`: row-parallel \
                         noise must come from `Pcg64::keyed`",
                        t.text, func.name
                    ),
                );
            }
        }
    }
}

/// **panic-free-serve** (transitive) — no `unwrap()`/`expect()` or
/// panicking macros anywhere in the closure of functions marked
/// `// lint: thread-body` (the serve stack's per-connection and worker
/// threads): a panic there kills one connection's thread and strands
/// its peer mid-protocol instead of surfacing an error reply.
///
/// Unguarded index expressions are checked in the *root* fns only —
/// the `// lint: guarded: <bounds invariant>` contract is written
/// against a fn's own locals and does not compose across calls, and
/// flagging every slice index in the compute kernels the workers reach
/// would drown the signal. Callee indexing is covered by the kernels'
/// own tier-1 tests.
fn panic_free_serve(
    files: &[SourceFile],
    graph: &CallGraph,
    out: &mut Vec<Diag>,
    debt: &mut Debt,
) {
    let roots = roots_where(files, graph, |x| x.has_pragma("thread-body"));
    let cl = graph.closure(files, &roots, PANIC_FREE_SERVE);
    spend(debt, PANIC_FREE_SERVE, cl.boundaries.len() + cl.pruned.len());
    for_member_tokens(files, graph, &cl, |ni, f, func, i| {
        let t = &f.toks[i];
        match t.kind {
            TokKind::Ident => {
                let name = t.text.as_str();
                let what = if matches!(name, "unwrap" | "expect") && is_call(f, i) {
                    Some(format!("`{name}()` can panic"))
                } else if PANIC_MACROS.contains(&name)
                    && f.sig_at(i + 1).is_some_and(|j| f.toks[j].is_punct('!'))
                {
                    Some(format!("`{name}!`"))
                } else {
                    None
                };
                if let Some(what) = what {
                    let suffix = via(graph, &cl, ni);
                    let msg = if suffix.is_empty() {
                        format!("{what} inside thread-body fn `{}`", func.name)
                    } else {
                        format!("{what} in `{}`{suffix}", func.name)
                    };
                    emit(f, out, debt, i, Some(func), PANIC_FREE_SERVE, msg);
                }
            }
            TokKind::Punct if t.is_punct('[') => {
                if !func.has_pragma("thread-body") || !is_index_expr(f, i) {
                    return;
                }
                if f.line_pragma(t.line, "guarded").is_some() {
                    return;
                }
                emit(
                    f,
                    out,
                    debt,
                    i,
                    Some(func),
                    PANIC_FREE_SERVE,
                    format!(
                        "index expression in thread-body fn `{}` without a \
                         `// lint: guarded:` bounds note",
                        func.name
                    ),
                );
            }
            _ => {}
        }
    });
}

/// Is the `[` at `i` an index expression (`expr[…]`) rather than an
/// array literal, attribute, slice pattern or type?
fn is_index_expr(f: &SourceFile, i: usize) -> bool {
    let Some(p) = (i.checked_sub(1)).and_then(|j| f.sig_before(j)) else {
        return false;
    };
    let prev = &f.toks[p];
    match prev.kind {
        TokKind::Ident => !NON_INDEX_KEYWORDS.contains(&prev.text.as_str()),
        TokKind::Punct => matches!(prev.punct(), Some(')') | Some(']')),
        _ => false,
    }
}

/// **no-wallclock-in-determinism** — `Instant::now`/`SystemTime::now`
/// reads are banned outside `util/benchx.rs`, the `coordinator` module
/// and explicitly pragma'd timing sites (`// lint: timing: <why>`).
/// Wallclock anywhere near the step path is how nondeterminism sneaks
/// into "bit-identical at any thread count" claims.
fn no_wallclock(f: &SourceFile, out: &mut Vec<Diag>, debt: &mut Debt) {
    // paths are relative to the lint root, so `coordinator/` may be the
    // leading component
    if f.path.ends_with("util/benchx.rs")
        || f.path.starts_with("coordinator/")
        || f.path.contains("/coordinator/")
    {
        return;
    }
    for i in 0..f.toks.len() {
        let t = &f.toks[i];
        if wallclock_now(f, i).is_none() {
            continue;
        }
        if f.line_pragma(t.line, "timing").is_some() {
            continue;
        }
        let fnc = f.enclosing_fn(i);
        emit(
            f,
            out,
            debt,
            i,
            fnc,
            NO_WALLCLOCK,
            format!(
                "`{}::now` outside the sanctioned timing modules; annotate \
                 with `// lint: timing: <why>` if this is a legitimate \
                 latency/throughput measurement",
                t.text
            ),
        );
    }
}

/// Is the token at `i` the `Instant`/`SystemTime` head of a `::now`
/// read (not an import or type position)? Returns the clock name.
fn wallclock_now<'a>(f: &'a SourceFile, i: usize) -> Option<&'a str> {
    let t = &f.toks[i];
    if !(t.is_ident("Instant") || t.is_ident("SystemTime")) {
        return None;
    }
    let c1 = f.sig_at(i + 1)?;
    if !f.toks[c1].is_punct(':') {
        return None;
    }
    let c2 = f.sig_at(c1 + 1)?;
    if !f.toks[c2].is_punct(':') {
        return None;
    }
    let m = f.sig_at(c2 + 1)?;
    f.toks[m].is_ident("now").then(|| t.text.as_str())
}

/// **atomic-ordering-audit** — every `Ordering::` stricter than
/// `Relaxed` needs an adjacent `// lint: ordering: <why>` justification:
/// the repo's concurrency is designed around data-parallel partitioning
/// plus joins, so a fence-bearing ordering is either load-bearing (and
/// its pairing must be written down) or an accident (and should be
/// `Relaxed`).
fn atomic_ordering(f: &SourceFile, out: &mut Vec<Diag>, debt: &mut Debt) {
    for i in 0..f.toks.len() {
        let t = &f.toks[i];
        if t.kind != TokKind::Ident || !STRICT_ORDERINGS.contains(&t.text.as_str()) {
            continue;
        }
        if path_head(f, i) != Some("Ordering") {
            continue;
        }
        if f.line_pragma(t.line, "ordering")
            .is_some_and(|p| !p.arg.is_empty())
        {
            continue;
        }
        let fnc = f.enclosing_fn(i);
        emit(
            f,
            out,
            debt,
            i,
            fnc,
            ATOMIC_ORDERING,
            format!(
                "`Ordering::{}` without an adjacent `// lint: ordering: <why>` \
                 justification",
                t.text
            ),
        );
    }
}

/// **determinism-taint** (transitive) — nothing reachable from the
/// photonic dispatch roots (`bank_linear`, `bank_dfa_gradient`,
/// `eval_into`) may read the wallclock or build a non-`keyed` `Pcg64`:
/// those are exactly the two ways a result could depend on scheduling
/// rather than on `(seed, op, lane)`. Stricter than the fn-local
/// rules it overlaps: a `// lint: timing:` pragma does *not* exempt a
/// site here — inside the dispatch closure there is no legitimate
/// latency measurement, only an `allow(determinism-taint)` contract.
fn determinism_taint(
    files: &[SourceFile],
    graph: &CallGraph,
    out: &mut Vec<Diag>,
    debt: &mut Debt,
) {
    let roots = roots_where(files, graph, |x| {
        DETERMINISM_ROOTS.contains(&x.name.as_str())
    });
    let cl = graph.closure(files, &roots, DETERMINISM_TAINT);
    spend(debt, DETERMINISM_TAINT, cl.boundaries.len() + cl.pruned.len());
    for_member_tokens(files, graph, &cl, |ni, f, func, i| {
        let t = &f.toks[i];
        if t.kind != TokKind::Ident {
            return;
        }
        let what = if let Some(clock) = wallclock_now(f, i) {
            Some(format!("`{clock}::now` read"))
        } else if RNG_CTORS.contains(&t.text.as_str())
            && path_head(f, i) == Some("Pcg64")
            && is_call(f, i)
        {
            Some(format!("non-keyed `Pcg64::{}`", t.text))
        } else {
            None
        };
        if let Some(what) = what {
            let suffix = via(graph, &cl, ni);
            emit(
                f,
                out,
                debt,
                i,
                Some(func),
                DETERMINISM_TAINT,
                format!(
                    "{what} in `{}` taints the photonic dispatch \
                     determinism contract{suffix}",
                    func.name
                ),
            );
        }
    });
}

/// **lock-order** — build the "holds `a`, acquires `b`" digraph over
/// lexical mutex identities (directly and through calls, see
/// [`CallGraph::order_edges`]) and flag every set of mutexes that can
/// be acquired in inconsistent order — a potential deadlock no test
/// run may ever hit. One finding per cycle, anchored at the first
/// witness site; suppress with `allow(lock-order)` on that line or fn.
fn lock_order(
    files: &[SourceFile],
    graph: &CallGraph,
    out: &mut Vec<Diag>,
    debt: &mut Debt,
) {
    let mut lock_debt = 0usize;
    let edges = graph.order_edges(files, &mut lock_debt);
    spend(debt, LOCK_ORDER, lock_debt);

    // mutually-reachable mutexes = an acquisition-order cycle
    let mut adj: BTreeMap<&str, Vec<&str>> = BTreeMap::new();
    for e in &edges {
        adj.entry(e.a.as_str()).or_default().push(e.b.as_str());
    }
    let reach = |from: &str| -> std::collections::BTreeSet<&str> {
        let mut seen = std::collections::BTreeSet::new();
        let mut stack = vec![from];
        while let Some(x) = stack.pop() {
            for &y in adj.get(x).map(Vec::as_slice).unwrap_or(&[]) {
                if seen.insert(y) {
                    stack.push(y);
                }
            }
        }
        seen
    };
    let mutexes: Vec<&str> = adj.keys().copied().collect();
    let reachable: BTreeMap<&str, _> =
        mutexes.iter().map(|&m| (m, reach(m))).collect();
    let mut seen_components: Vec<Vec<&str>> = Vec::new();
    for &m in &mutexes {
        let comp: Vec<&str> = mutexes
            .iter()
            .copied()
            .filter(|&x| {
                (x == m || reachable[m].contains(x)) && reachable[x].contains(m)
            })
            .collect();
        if comp.len() < 2 || seen_components.contains(&comp) {
            continue;
        }
        seen_components.push(comp.clone());
        // the cycle's witness edges, in deterministic order
        let mut witnesses: Vec<&super::graph::OrderEdge> = edges
            .iter()
            .filter(|e| comp.contains(&e.a.as_str()) && comp.contains(&e.b.as_str()))
            .collect();
        witnesses.sort_by_key(|e| {
            (&files[graph.nodes[e.node].file].path, e.line, &e.a, &e.b)
        });
        let Some(first) = witnesses.first() else { continue };
        let f = &files[graph.nodes[first.node].file];
        let func = &f.fns[graph.nodes[first.node].func];
        let detail = witnesses
            .iter()
            .map(|e| {
                let nf = &files[graph.nodes[e.node].file];
                format!(
                    "{} -> {} ({}:{} in `{}`)",
                    e.a, e.b, nf.path, e.line, graph.nodes[e.node].qual
                )
            })
            .collect::<Vec<_>>()
            .join(", ");
        emit_at_line(
            f,
            out,
            debt,
            first.line,
            Some(func),
            LOCK_ORDER,
            format!(
                "inconsistent lock acquisition order among {{{}}}: {detail}; \
                 pick one order or write an `allow(lock-order)` contract",
                comp.join(", ")
            ),
        );
    }
}
