//! The six repo-invariant lint rules.
//!
//! Each rule is a named, individually-suppressable check over a
//! [`SourceFile`]'s token stream (see DESIGN.md, "Static analysis", for
//! the invariant each one guards). Findings inside `#[cfg(test)]`
//! modules are skipped wholesale — test code may allocate, panic and
//! read the clock freely. Suppression is explicit and local: a
//! function-level `// lint: allow(<rule>)` pragma, or a line-level
//! pragma (`allow`, `timing`, `ordering`, `guarded`) on the flagged
//! line or the comment line(s) directly above it.

use super::ast::{Function, SourceFile};
use super::lexer::TokKind;

/// One finding: file, line, rule name and a human-readable message.
#[derive(Debug, Clone, PartialEq)]
pub struct Diag {
    pub file: String,
    pub line: u32,
    pub rule: &'static str,
    pub msg: String,
}

/// Every rule name, in the order they run. Fixture tests assert each
/// one fires; `pdfa lint --json` records the list in the report.
pub const RULES: [&str; 6] = [
    HOT_PATH_ALLOC,
    NO_RAW_THREAD_CAP,
    KEYED_RNG_ONLY,
    PANIC_FREE_SERVE,
    NO_WALLCLOCK,
    ATOMIC_ORDERING,
];

pub const HOT_PATH_ALLOC: &str = "hot-path-alloc";
pub const NO_RAW_THREAD_CAP: &str = "no-raw-thread-cap";
pub const KEYED_RNG_ONLY: &str = "keyed-rng-only";
pub const PANIC_FREE_SERVE: &str = "panic-free-serve";
pub const NO_WALLCLOCK: &str = "no-wallclock-in-determinism";
pub const ATOMIC_ORDERING: &str = "atomic-ordering-audit";

/// Allocating method/associated-fn idents banned in `hot-path` bodies.
const ALLOC_CALLS: [&str; 4] = ["clone", "to_vec", "collect", "with_capacity"];
/// Allocating macros banned in `hot-path` bodies.
const ALLOC_MACROS: [&str; 2] = ["format", "vec"];
/// Panicking macros banned in `thread-body` bodies.
const PANIC_MACROS: [&str; 4] = ["panic", "unreachable", "todo", "unimplemented"];
/// Atomic orderings stricter than `Relaxed` (the cmp::Ordering variants
/// Less/Equal/Greater never collide with these names).
const STRICT_ORDERINGS: [&str; 4] = ["Acquire", "Release", "AcqRel", "SeqCst"];
/// Keywords that may directly precede `[` without forming an index
/// expression (`for x in [..]`, `return [..]`, …).
const NON_INDEX_KEYWORDS: [&str; 10] = [
    "in", "return", "break", "if", "else", "match", "let", "mut", "ref", "box",
];

/// Run every rule over `f`, appending findings to `out`.
pub fn check_file(f: &SourceFile, out: &mut Vec<Diag>) {
    hot_path_alloc(f, out);
    no_raw_thread_cap(f, out);
    keyed_rng_only(f, out);
    panic_free_serve(f, out);
    no_wallclock(f, out);
    atomic_ordering(f, out);
}

/// Shared finding constructor: drops the diag if the token is in test
/// code or a fn/line-level suppression covers it.
fn emit(
    f: &SourceFile,
    out: &mut Vec<Diag>,
    idx: usize,
    fnc: Option<&Function>,
    rule: &'static str,
    msg: String,
) {
    if f.in_test(idx) {
        return;
    }
    let line = f.toks[idx].line;
    if let Some(func) = fnc {
        if func.allows(rule) {
            return;
        }
    }
    if f.line_pragma(line, "allow")
        .is_some_and(|p| p.arg == rule)
    {
        return;
    }
    out.push(Diag { file: f.path.clone(), line, rule, msg });
}

/// Is the ident at `i` called (next significant token `(`), possibly
/// through a turbofish/path (`::`)?
fn is_call(f: &SourceFile, i: usize) -> bool {
    match f.sig_at(i + 1) {
        Some(j) => f.toks[j].is_punct('(') || f.toks[j].is_punct(':'),
        None => false,
    }
}

/// The path head two significant tokens back, if `i` is reached via
/// `Head::ident` (returns the text of `Head`).
fn path_head<'a>(f: &'a SourceFile, i: usize) -> Option<&'a str> {
    let c1 = f.sig_before(i.checked_sub(1)?)?;
    if !f.toks[c1].is_punct(':') {
        return None;
    }
    let c2 = f.sig_before(c1.checked_sub(1)?)?;
    if !f.toks[c2].is_punct(':') {
        return None;
    }
    let h = f.sig_before(c2.checked_sub(1)?)?;
    (f.toks[h].kind == TokKind::Ident).then(|| f.toks[h].text.as_str())
}

/// **hot-path-alloc** — no allocating calls or macros inside functions
/// marked `// lint: hot-path`: `clone()`, `to_vec()`, `collect()`,
/// `with_capacity()`, `Vec::new()`, `Box::new()`, `String::from()`,
/// `format!`, `vec!`. The steady-state serve and photonic dispatch
/// paths are allocation-free by contract (`tests/alloc_*.rs` sample
/// them at runtime; this rule checks every call site statically).
fn hot_path_alloc(f: &SourceFile, out: &mut Vec<Diag>) {
    for func in f.fns.iter().filter(|x| x.has_pragma("hot-path")) {
        for i in func.body.0..func.body.1 {
            let t = &f.toks[i];
            if t.kind != TokKind::Ident {
                continue;
            }
            let name = t.text.as_str();
            let flagged = if ALLOC_CALLS.contains(&name) && is_call(f, i) {
                Some(name.to_string())
            } else if ALLOC_MACROS.contains(&name)
                && f.sig_at(i + 1).is_some_and(|j| f.toks[j].is_punct('!'))
            {
                Some(format!("{name}!"))
            } else if name == "new" && is_call(f, i) {
                match path_head(f, i) {
                    Some(h @ ("Vec" | "Box")) => Some(format!("{h}::new")),
                    _ => None,
                }
            } else if name == "from"
                && is_call(f, i)
                && path_head(f, i) == Some("String")
            {
                Some("String::from".to_string())
            } else {
                None
            };
            if let Some(what) = flagged {
                emit(
                    f,
                    out,
                    i,
                    Some(func),
                    HOT_PATH_ALLOC,
                    format!("`{what}` allocates inside hot-path fn `{}`", func.name),
                );
            }
        }
    }
}

/// **no-raw-thread-cap** — `ops::set_thread_cap` is callable only from
/// `ThreadCapGuard` (its defining module, `tensor/ops.rs`, is exempt).
/// Raw calls from concurrently running scopes race on the process
/// global and leak their override; scoped guards serialize and restore.
fn no_raw_thread_cap(f: &SourceFile, out: &mut Vec<Diag>) {
    if f.path.ends_with("tensor/ops.rs") {
        return;
    }
    for i in 0..f.toks.len() {
        let t = &f.toks[i];
        if !t.is_ident("set_thread_cap") {
            continue;
        }
        // skip the declaration itself and `use` imports (no call parens)
        if f.sig_before(i.saturating_sub(1)).is_some_and(|j| f.toks[j].is_ident("fn")) {
            continue;
        }
        if !f.sig_at(i + 1).is_some_and(|j| f.toks[j].is_punct('(')) {
            continue;
        }
        let fnc = f.enclosing_fn(i);
        emit(
            f,
            out,
            i,
            fnc,
            NO_RAW_THREAD_CAP,
            "raw `set_thread_cap` call outside `ThreadCapGuard`; use a \
             scoped guard (or `// lint: allow(no-raw-thread-cap)` with a \
             written contract)"
                .to_string(),
        );
    }
}

/// **keyed-rng-only** — inside row-parallel eval regions (functions
/// marked `// lint: rng-region`) RNGs may only be built with
/// `Pcg64::keyed(seed, op, lane)`: sequentially-seeded streams make
/// results depend on which worker ran which row, breaking the
/// bit-identical-at-any-`--threads` contract the photonic results
/// depend on.
fn keyed_rng_only(f: &SourceFile, out: &mut Vec<Diag>) {
    for func in f.fns.iter().filter(|x| x.has_pragma("rng-region")) {
        for i in func.body.0..func.body.1 {
            let t = &f.toks[i];
            if t.kind != TokKind::Ident {
                continue;
            }
            let banned = matches!(
                t.text.as_str(),
                "new" | "seed" | "fork" | "from_state_bytes"
            );
            if banned && path_head(f, i) == Some("Pcg64") && is_call(f, i) {
                emit(
                    f,
                    out,
                    i,
                    Some(func),
                    KEYED_RNG_ONLY,
                    format!(
                        "`Pcg64::{}` inside rng-region fn `{}`: row-parallel \
                         noise must come from `Pcg64::keyed`",
                        t.text, func.name
                    ),
                );
            }
        }
    }
}

/// **panic-free-serve** — no `unwrap()`/`expect()`, panicking macros,
/// or unguarded index expressions inside functions marked
/// `// lint: thread-body` (the serve stack's per-connection and worker
/// threads): a panic there kills one connection's thread and strands
/// its peer mid-protocol instead of surfacing an error reply. Index
/// expressions need a `// lint: guarded: <bounds invariant>` pragma.
fn panic_free_serve(f: &SourceFile, out: &mut Vec<Diag>) {
    for func in f.fns.iter().filter(|x| x.has_pragma("thread-body")) {
        for i in func.body.0..func.body.1 {
            let t = &f.toks[i];
            match t.kind {
                TokKind::Ident => {
                    let name = t.text.as_str();
                    if matches!(name, "unwrap" | "expect") && is_call(f, i) {
                        emit(
                            f,
                            out,
                            i,
                            Some(func),
                            PANIC_FREE_SERVE,
                            format!(
                                "`{}()` can panic inside thread-body fn `{}`",
                                name, func.name
                            ),
                        );
                    } else if PANIC_MACROS.contains(&name)
                        && f.sig_at(i + 1).is_some_and(|j| f.toks[j].is_punct('!'))
                    {
                        emit(
                            f,
                            out,
                            i,
                            Some(func),
                            PANIC_FREE_SERVE,
                            format!(
                                "`{}!` inside thread-body fn `{}`",
                                name, func.name
                            ),
                        );
                    }
                }
                TokKind::Punct if t.is_punct('[') => {
                    if !is_index_expr(f, i) {
                        continue;
                    }
                    if f.line_pragma(t.line, "guarded").is_some() {
                        continue;
                    }
                    emit(
                        f,
                        out,
                        i,
                        Some(func),
                        PANIC_FREE_SERVE,
                        format!(
                            "index expression in thread-body fn `{}` without a \
                             `// lint: guarded:` bounds note",
                            func.name
                        ),
                    );
                }
                _ => {}
            }
        }
    }
}

/// Is the `[` at `i` an index expression (`expr[…]`) rather than an
/// array literal, attribute, slice pattern or type?
fn is_index_expr(f: &SourceFile, i: usize) -> bool {
    let Some(p) = (i.checked_sub(1)).and_then(|j| f.sig_before(j)) else {
        return false;
    };
    let prev = &f.toks[p];
    match prev.kind {
        TokKind::Ident => !NON_INDEX_KEYWORDS.contains(&prev.text.as_str()),
        TokKind::Punct => matches!(prev.punct(), Some(')') | Some(']')),
        _ => false,
    }
}

/// **no-wallclock-in-determinism** — `Instant::now`/`SystemTime::now`
/// reads are banned outside `util/benchx.rs`, the `coordinator` module
/// and explicitly pragma'd timing sites (`// lint: timing: <why>`).
/// Wallclock anywhere near the step path is how nondeterminism sneaks
/// into "bit-identical at any thread count" claims.
fn no_wallclock(f: &SourceFile, out: &mut Vec<Diag>) {
    // paths are relative to the lint root, so `coordinator/` may be the
    // leading component
    if f.path.ends_with("util/benchx.rs")
        || f.path.starts_with("coordinator/")
        || f.path.contains("/coordinator/")
    {
        return;
    }
    for i in 0..f.toks.len() {
        let t = &f.toks[i];
        if !(t.is_ident("Instant") || t.is_ident("SystemTime")) {
            continue;
        }
        // flag only the `::now` read, not imports or type positions
        let Some(c1) = f.sig_at(i + 1) else { continue };
        if !f.toks[c1].is_punct(':') {
            continue;
        }
        let Some(c2) = f.sig_at(c1 + 1) else { continue };
        if !f.toks[c2].is_punct(':') {
            continue;
        }
        let Some(m) = f.sig_at(c2 + 1) else { continue };
        if !f.toks[m].is_ident("now") {
            continue;
        }
        if f.line_pragma(t.line, "timing").is_some() {
            continue;
        }
        let fnc = f.enclosing_fn(i);
        emit(
            f,
            out,
            i,
            fnc,
            NO_WALLCLOCK,
            format!(
                "`{}::now` outside the sanctioned timing modules; annotate \
                 with `// lint: timing: <why>` if this is a legitimate \
                 latency/throughput measurement",
                t.text
            ),
        );
    }
}

/// **atomic-ordering-audit** — every `Ordering::` stricter than
/// `Relaxed` needs an adjacent `// lint: ordering: <why>` justification:
/// the repo's concurrency is designed around data-parallel partitioning
/// plus joins, so a fence-bearing ordering is either load-bearing (and
/// its pairing must be written down) or an accident (and should be
/// `Relaxed`).
fn atomic_ordering(f: &SourceFile, out: &mut Vec<Diag>) {
    for i in 0..f.toks.len() {
        let t = &f.toks[i];
        if t.kind != TokKind::Ident || !STRICT_ORDERINGS.contains(&t.text.as_str()) {
            continue;
        }
        if path_head(f, i) != Some("Ordering") {
            continue;
        }
        if f.line_pragma(t.line, "ordering")
            .is_some_and(|p| !p.arg.is_empty())
        {
            continue;
        }
        let fnc = f.enclosing_fn(i);
        emit(
            f,
            out,
            i,
            fnc,
            ATOMIC_ORDERING,
            format!(
                "`Ordering::{}` without an adjacent `// lint: ordering: <why>` \
                 justification",
                t.text
            ),
        );
    }
}
