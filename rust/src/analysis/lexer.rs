//! Minimal Rust lexer for the in-repo static-analysis pass.
//!
//! Produces a flat token stream — identifiers, lifetimes, literals,
//! punctuation and (crucially) comments, each tagged with its 1-based
//! source line — from which [`super::ast`] recovers item/function
//! structure and lint pragmas. This is a *lexer*, not a compiler front
//! end: it only needs to be exact about the things that can hide or
//! fabricate rule matches, namely string literals (including raw and
//! byte strings), character literals vs lifetimes, and line/block
//! comments (including nesting and multi-line spans). Everything the
//! rules match on is an identifier or punctuation token, so a banned
//! call inside a string or comment can never fire, and a pragma inside
//! a string can never suppress.
//!
//! No `syn`, no proc-macro machinery: the default build stays hermetic.

/// Lexical class of one token.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`fn`, `unwrap`, `Ordering`, …).
    Ident,
    /// Lifetime (`'a`, `'static`) — distinguished from char literals.
    Lifetime,
    /// Numeric literal (`0x6b`, `1e-3`, `0.28f64`, …).
    Number,
    /// Any string-ish literal: `"…"`, `r"…"`, `r#"…"#`, `b"…"`, `c"…"`.
    Str,
    /// Character or byte literal (`'x'`, `b'\n'`).
    Char,
    /// `// …` comment (doc comments included), text kept for pragmas.
    LineComment,
    /// `/* … */` comment (nesting and multi-line spans handled).
    BlockComment,
    /// Single punctuation character (`{`, `:`, `!`, …).
    Punct,
}

/// One token: kind, verbatim text and the line its first byte sits on.
#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
}

impl Tok {
    /// The token's punctuation character, if it is punctuation.
    pub fn punct(&self) -> Option<char> {
        match self.kind {
            TokKind::Punct => self.text.chars().next(),
            _ => None,
        }
    }

    pub fn is_punct(&self, c: char) -> bool {
        self.punct() == Some(c)
    }

    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == TokKind::Ident && self.text == s
    }

    pub fn is_comment(&self) -> bool {
        matches!(self.kind, TokKind::LineComment | TokKind::BlockComment)
    }
}

/// Tokenize `src`. Never fails: unterminated literals/comments are
/// consumed to end of input (the linter must stay robust on any tree).
pub fn lex(src: &str) -> Vec<Tok> {
    Lexer { src: src.as_bytes(), pos: 0, line: 1, toks: Vec::new() }.run()
}

struct Lexer<'a> {
    src: &'a [u8],
    pos: usize,
    line: u32,
    toks: Vec<Tok>,
}

impl Lexer<'_> {
    fn run(mut self) -> Vec<Tok> {
        while self.pos < self.src.len() {
            let c = self.src[self.pos];
            match c {
                b'\n' => {
                    self.line += 1;
                    self.pos += 1;
                }
                _ if c.is_ascii_whitespace() => self.pos += 1,
                b'/' if self.peek(1) == Some(b'/') => self.line_comment(),
                b'/' if self.peek(1) == Some(b'*') => self.block_comment(),
                b'"' => self.string(0),
                b'\'' => self.char_or_lifetime(),
                _ if c.is_ascii_digit() => self.number(),
                _ if c == b'_' || c.is_ascii_alphabetic() || c >= 0x80 => {
                    self.ident_or_prefixed_string()
                }
                _ => {
                    self.push(TokKind::Punct, self.pos, self.pos + 1, self.line);
                    self.pos += 1;
                }
            }
        }
        self.toks
    }

    fn peek(&self, ahead: usize) -> Option<u8> {
        self.src.get(self.pos + ahead).copied()
    }

    fn push(&mut self, kind: TokKind, start: usize, end: usize, line: u32) {
        let text = String::from_utf8_lossy(&self.src[start..end]).into_owned();
        self.toks.push(Tok { kind, text, line });
    }

    fn line_comment(&mut self) {
        let start = self.pos;
        while self.pos < self.src.len() && self.src[self.pos] != b'\n' {
            self.pos += 1;
        }
        self.push(TokKind::LineComment, start, self.pos, self.line);
    }

    /// `/* … */` with nesting, spanning any number of lines. The token
    /// is tagged with its *opening* line.
    fn block_comment(&mut self) {
        let (start, start_line) = (self.pos, self.line);
        self.pos += 2;
        let mut depth = 1usize;
        while self.pos < self.src.len() && depth > 0 {
            match self.src[self.pos] {
                b'/' if self.peek(1) == Some(b'*') => {
                    depth += 1;
                    self.pos += 2;
                }
                b'*' if self.peek(1) == Some(b'/') => {
                    depth -= 1;
                    self.pos += 2;
                }
                b'\n' => {
                    self.line += 1;
                    self.pos += 1;
                }
                _ => self.pos += 1,
            }
        }
        self.push(TokKind::BlockComment, start, self.pos, start_line);
    }

    /// Cooked string starting at the opening quote; `prefix_len` bytes of
    /// `b`/`c` prefix are already consumed into the token. Multi-line
    /// bodies and escaped quotes/backslashes are handled.
    fn string(&mut self, prefix_len: usize) {
        let (start, start_line) = (self.pos - prefix_len, self.line);
        self.pos += 1; // opening quote
        while self.pos < self.src.len() {
            match self.src[self.pos] {
                b'\\' => self.pos += 2, // skip the escaped byte
                b'"' => {
                    self.pos += 1;
                    break;
                }
                b'\n' => {
                    self.line += 1;
                    self.pos += 1;
                }
                _ => self.pos += 1,
            }
        }
        self.push(TokKind::Str, start, self.pos.min(self.src.len()), start_line);
    }

    /// Raw string starting at the `r`'s offset: `r"…"`, `r#"…"#` with any
    /// number of hashes, no escapes, multi-line. `hashes` were counted by
    /// the caller; `self.pos` sits on the opening quote.
    fn raw_string(&mut self, start: usize, hashes: usize) {
        let start_line = self.line;
        self.pos += 1; // opening quote
        while self.pos < self.src.len() {
            match self.src[self.pos] {
                b'"' => {
                    let mut h = 0;
                    while h < hashes && self.peek(1 + h) == Some(b'#') {
                        h += 1;
                    }
                    self.pos += 1;
                    if h == hashes {
                        self.pos += hashes;
                        break;
                    }
                }
                b'\n' => {
                    self.line += 1;
                    self.pos += 1;
                }
                _ => self.pos += 1,
            }
        }
        self.push(TokKind::Str, start, self.pos.min(self.src.len()), start_line);
    }

    /// `'a` (lifetime) vs `'x'` / `'\n'` (char literal). A quote followed
    /// by an identifier char that is *not* closed by a quote right after
    /// is a lifetime; everything else is a char literal.
    fn char_or_lifetime(&mut self) {
        let start = self.pos;
        let next = self.peek(1);
        let is_ident_start =
            next.is_some_and(|c| c == b'_' || c.is_ascii_alphabetic());
        if is_ident_start && self.peek(2) != Some(b'\'') {
            self.pos += 1;
            while self
                .peek(0)
                .is_some_and(|c| c == b'_' || c.is_ascii_alphanumeric())
            {
                self.pos += 1;
            }
            self.push(TokKind::Lifetime, start, self.pos, self.line);
            return;
        }
        // char literal: consume escapes until the closing quote
        self.pos += 1;
        while self.pos < self.src.len() {
            match self.src[self.pos] {
                b'\\' => self.pos += 2,
                b'\'' => {
                    self.pos += 1;
                    break;
                }
                b'\n' => break, // stray quote, not a literal — bail out
                _ => self.pos += 1,
            }
        }
        self.push(TokKind::Char, start, self.pos.min(self.src.len()), self.line);
    }

    fn number(&mut self) {
        let start = self.pos;
        let mut prev = 0u8;
        while let Some(c) = self.peek(0) {
            let take = c.is_ascii_alphanumeric()
                || c == b'_'
                // `1.5` yes; `0..10` and `x.method()` no
                || (c == b'.' && self.peek(1).is_some_and(|d| d.is_ascii_digit()))
                // exponent sign: `1e-3`, `2E+5`
                || ((c == b'+' || c == b'-') && (prev == b'e' || prev == b'E'));
            if !take {
                break;
            }
            prev = c;
            self.pos += 1;
        }
        self.push(TokKind::Number, start, self.pos, self.line);
    }

    /// An identifier, unless it is a string-literal prefix (`r"`, `r#"`,
    /// `b"`, `br#"`, `c"`, `b'…'`) in which case the literal is lexed.
    fn ident_or_prefixed_string(&mut self) {
        let start = self.pos;
        while self.peek(0).is_some_and(|c| {
            c == b'_' || c.is_ascii_alphanumeric() || c >= 0x80
        }) {
            self.pos += 1;
        }
        let ident = &self.src[start..self.pos];
        let raw_prefix = matches!(ident, b"r" | b"br" | b"cr" | b"b" | b"c");
        if raw_prefix {
            match self.peek(0) {
                // cooked with prefix: b"…", c"…" (escapes lex like "…")
                Some(b'"') if ident == b"b" || ident == b"c" => {
                    self.string(ident.len());
                    return;
                }
                // raw with zero hashes: r"…", br"…", cr"…"
                Some(b'"') => {
                    self.raw_string(start, 0);
                    return;
                }
                Some(b'#') => {
                    // r#"…"# / br##"…"## — count hashes then expect a quote
                    let mut hashes = 0;
                    while self.peek(hashes) == Some(b'#') {
                        hashes += 1;
                    }
                    if self.peek(hashes) == Some(b'"') {
                        self.pos += hashes;
                        self.raw_string(start, hashes);
                        return;
                    }
                    // r#ident raw identifier: fall through, emit ident
                }
                // b'…' byte char literal
                Some(b'\'') if ident == b"b" => {
                    self.pos += 1;
                    while self.pos < self.src.len() {
                        match self.src[self.pos] {
                            b'\\' => self.pos += 2,
                            b'\'' => {
                                self.pos += 1;
                                break;
                            }
                            _ => self.pos += 1,
                        }
                    }
                    self.push(
                        TokKind::Char,
                        start,
                        self.pos.min(self.src.len()),
                        self.line,
                    );
                    return;
                }
                _ => {}
            }
        }
        self.push(TokKind::Ident, start, self.pos, self.line);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        lex(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn idents_puncts_numbers() {
        let ts = kinds("fn f(x: usize) -> u64 { x as u64 + 0x1f }");
        assert_eq!(ts[0], (TokKind::Ident, "fn".into()));
        assert_eq!(ts[1], (TokKind::Ident, "f".into()));
        assert!(ts.iter().any(|t| *t == (TokKind::Number, "0x1f".into())));
    }

    #[test]
    fn strings_hide_their_contents() {
        let ts = kinds(r#"let s = "Instant::now() // not code";"#);
        assert_eq!(
            ts.iter().filter(|t| t.0 == TokKind::Ident).count(),
            2, // let, s
        );
        assert_eq!(ts.iter().filter(|t| t.0 == TokKind::Str).count(), 1);
    }

    #[test]
    fn raw_strings_with_hashes_and_newlines() {
        let src = "let s = r#\"line1 \"quoted\"\nline2 unwrap()\"#; next";
        let ts = lex(src);
        let s = ts.iter().find(|t| t.kind == TokKind::Str).unwrap();
        assert!(s.text.contains("line2"));
        // the token after the raw string is on line 2
        let next = ts.iter().find(|t| t.is_ident("next")).unwrap();
        assert_eq!(next.line, 2);
    }

    #[test]
    fn nested_block_comments_span_lines() {
        let src = "a /* outer /* inner */ still\ncomment */ b";
        let ts = lex(src);
        assert_eq!(ts.len(), 3);
        assert_eq!(ts[1].kind, TokKind::BlockComment);
        assert!(ts[1].text.contains("inner"));
        assert_eq!(ts[2].text, "b");
        assert_eq!(ts[2].line, 2);
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let ts = kinds("fn f<'a>(x: &'a str) -> char { 'x' }");
        assert_eq!(ts.iter().filter(|t| t.0 == TokKind::Lifetime).count(), 2);
        assert_eq!(ts.iter().filter(|t| t.0 == TokKind::Char).count(), 1);
    }

    #[test]
    fn line_comments_keep_text_for_pragmas() {
        let ts = lex("x // lint: hot-path\ny");
        assert_eq!(ts[1].kind, TokKind::LineComment);
        assert_eq!(ts[1].text, "// lint: hot-path");
        assert_eq!(ts[2].line, 2);
    }

    #[test]
    fn escaped_quotes_do_not_end_strings() {
        let ts = lex(r#"let s = "a\"b\\"; done"#);
        assert!(ts.iter().any(|t| t.is_ident("done")));
        assert_eq!(ts.iter().filter(|t| t.kind == TokKind::Str).count(), 1);
    }

    #[test]
    fn unterminated_input_never_panics() {
        for src in ["\"abc", "/* never closed", "r#\"open", "'\\", "b'"] {
            let _ = lex(src); // must terminate without panicking
        }
    }
}
