//! Streaming JSON lexer + the NDJSON wire codec for `pdfa serve`.
//!
//! The DOM parser in [`super::json`] builds a `BTreeMap`+`String` tree —
//! fine for manifests and reports, far too allocation-heavy for a request
//! hot path. This module is the complement: a callback/visitor lexer that
//! walks a JSON document and emits borrowed [`Event`]s, plus specialized
//! codecs for the serving wire format that parse straight into reusable
//! buffers:
//!
//! * request line  — `{"x":[<f32>...]}` with an optional `"id":<u64>`
//! * success reply — `{"id":<u64>,"pred":<usize>,"logits":[<f32>...]}`
//! * error reply   — `{"id":<u64>,"error":"<message>"}`
//!
//! At steady state the codec performs **zero heap allocations per
//! request**: [`parse_request`] fills a caller-owned `Vec<f32>`,
//! [`write_reply`]/[`write_error`] fill a caller-owned `String`, number
//! tokens are handed out as borrowed `&str` slices (`Event::Num`) so the
//! caller parses `f32`/`u64` directly without an intermediate `f64` DOM
//! node, and escaped strings decode into the lexer's persistent scratch
//! buffer. Allocation-freedom is pinned by `tests/alloc_hotpath.rs` with
//! a counting global allocator.
//!
//! Floats survive the wire bit-exactly: serialization uses Rust's
//! shortest-round-trip `Display` and parsing is correctly rounded, so
//! `parse(write(v)) == v` for every finite `f32` — the property the
//! serve-path bit-identity guarantee rests on.

use std::fmt::Write as _;

use crate::{Error, Result};

/// Nesting depth cap: a parser guard, not a wire limit (request lines
/// are depth 2). Keeps adversarial `[[[[...` input from overflowing the
/// recursive-descent stack.
const MAX_DEPTH: usize = 128;

/// One structural event emitted by [`Lexer::lex`].
///
/// Borrowed payloads (`Key`, `Str`, `Num`) are valid only for the
/// duration of the visitor call: string data may live in the lexer's
/// reused scratch buffer. `Num` is the *raw token text* — the visitor
/// picks the parse target (`f32`, `u64`, ...) so no precision is forced
/// by the lexer.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Event<'a> {
    BeginObject,
    EndObject,
    BeginArray,
    EndArray,
    /// Object key (emitted before its value's events).
    Key(&'a str),
    Str(&'a str),
    /// Raw number token, syntax-checked against the JSON grammar.
    Num(&'a str),
    Bool(bool),
    Null,
}

/// Reusable streaming lexer. Holds only the escape-decoding scratch
/// buffer, so a long-lived connection pays for string unescaping
/// capacity once.
#[derive(Default)]
pub struct Lexer {
    scratch: String,
}

impl Lexer {
    pub fn new() -> Lexer {
        Lexer::default()
    }

    /// Lex one complete JSON document, calling `visit` for every event.
    /// Trailing non-whitespace is an error (NDJSON: one value per line).
    /// An `Err` from `visit` aborts the walk and is returned verbatim.
    // lint: hot-path
    pub fn lex(
        &mut self,
        src: &str,
        visit: &mut dyn FnMut(Event) -> Result<()>,
    ) -> Result<()> {
        let mut lx = Lex {
            src,
            bytes: src.as_bytes(),
            pos: 0,
            scratch: &mut self.scratch,
            visit,
        };
        lx.skip_ws();
        lx.value(0)?;
        lx.skip_ws();
        if lx.pos != lx.bytes.len() {
            return Err(lx.err("trailing data after JSON value"));
        }
        Ok(())
    }
}

struct Lex<'s, 'v> {
    src: &'s str,
    bytes: &'s [u8],
    pos: usize,
    scratch: &'v mut String,
    visit: &'v mut dyn FnMut(Event) -> Result<()>,
}

impl Lex<'_, '_> {
    fn err(&self, msg: impl Into<String>) -> Error {
        Error::Json { offset: self.pos, msg: msg.into() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn literal(&mut self, lit: &str) -> Result<()> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(())
        } else {
            // lint: allow(hot-path-alloc) — cold path, only on malformed input
            Err(self.err(format!("invalid literal, expected '{lit}'")))
        }
    }

    // lint: hot-path
    fn value(&mut self, depth: usize) -> Result<()> {
        if depth >= MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        match self.peek() {
            Some(b'{') => {
                self.pos += 1;
                (self.visit)(Event::BeginObject)?;
                self.skip_ws();
                if self.peek() == Some(b'}') {
                    self.pos += 1;
                    return (self.visit)(Event::EndObject);
                }
                loop {
                    self.skip_ws();
                    self.string_event(true)?;
                    self.skip_ws();
                    if self.bump() != Some(b':') {
                        self.pos = self.pos.saturating_sub(1);
                        return Err(self.err("expected ':' after object key"));
                    }
                    self.skip_ws();
                    self.value(depth + 1)?;
                    self.skip_ws();
                    match self.bump() {
                        Some(b',') => continue,
                        Some(b'}') => return (self.visit)(Event::EndObject),
                        _ => return Err(self.err("expected ',' or '}' in object")),
                    }
                }
            }
            Some(b'[') => {
                self.pos += 1;
                (self.visit)(Event::BeginArray)?;
                self.skip_ws();
                if self.peek() == Some(b']') {
                    self.pos += 1;
                    return (self.visit)(Event::EndArray);
                }
                loop {
                    self.skip_ws();
                    self.value(depth + 1)?;
                    self.skip_ws();
                    match self.bump() {
                        Some(b',') => continue,
                        Some(b']') => return (self.visit)(Event::EndArray),
                        _ => return Err(self.err("expected ',' or ']' in array")),
                    }
                }
            }
            Some(b'"') => self.string_event(false),
            Some(b't') => {
                self.literal("true")?;
                (self.visit)(Event::Bool(true))
            }
            Some(b'f') => {
                self.literal("false")?;
                (self.visit)(Event::Bool(false))
            }
            Some(b'n') => {
                self.literal("null")?;
                (self.visit)(Event::Null)
            }
            Some(b'-' | b'0'..=b'9') => self.number_event(),
            // lint: allow(hot-path-alloc) — cold path, only on malformed input
            Some(c) => Err(self.err(format!("unexpected byte '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    /// Emit `Key`/`Str`. Escape-free strings are borrowed straight from
    /// the input; escaped ones decode into the persistent scratch buffer
    /// (no allocation once its capacity is warm).
    // lint: hot-path
    fn string_event(&mut self, key: bool) -> Result<()> {
        if self.bump() != Some(b'"') {
            self.pos = self.pos.saturating_sub(1);
            return Err(self.err("expected string"));
        }
        let start = self.pos;
        let mut i = self.pos;
        while i < self.bytes.len() {
            let b = self.bytes[i];
            if b == b'"' {
                let s = &self.src[start..i];
                self.pos = i + 1;
                return (self.visit)(if key { Event::Key(s) } else { Event::Str(s) });
            }
            if b == b'\\' || b < 0x20 {
                break;
            }
            i += 1;
        }
        if self.bytes.get(i).copied() == Some(b'\\') {
            // slow path: copy the clean prefix, then decode escapes
            self.scratch.clear();
            self.scratch.push_str(&self.src[start..i]);
            self.pos = i;
            self.decode_escaped_tail()?;
            let s: &str = self.scratch;
            return (self.visit)(if key { Event::Key(s) } else { Event::Str(s) });
        }
        self.pos = i;
        if i < self.bytes.len() {
            Err(self.err("control character in string"))
        } else {
            Err(self.err("unterminated string"))
        }
    }

    /// Continue an escaped string from `pos` into `scratch`, consuming
    /// the closing quote.
    // lint: hot-path
    fn decode_escaped_tail(&mut self) -> Result<()> {
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(()),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => self.scratch.push('"'),
                    Some(b'\\') => self.scratch.push('\\'),
                    Some(b'/') => self.scratch.push('/'),
                    Some(b'b') => self.scratch.push('\u{0008}'),
                    Some(b'f') => self.scratch.push('\u{000C}'),
                    Some(b'n') => self.scratch.push('\n'),
                    Some(b'r') => self.scratch.push('\r'),
                    Some(b't') => self.scratch.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("expected low surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            char::from_u32(0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00))
                        } else {
                            char::from_u32(cp)
                        };
                        self.scratch
                            .push(c.ok_or_else(|| self.err("invalid codepoint"))?);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(b) if b < 0x20 => {
                    return Err(self.err("control character in string"))
                }
                Some(b) => {
                    // multibyte UTF-8 passthrough (input is a valid &str)
                    let len = utf8_len(b);
                    let st = self.pos - 1;
                    for _ in 1..len {
                        self.bump();
                    }
                    let chunk = self
                        .src
                        .get(st..self.pos)
                        .ok_or_else(|| self.err("invalid utf-8"))?;
                    self.scratch.push_str(chunk);
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("eof in \\u escape"))?;
            let d = (b as char)
                .to_digit(16)
                .ok_or_else(|| self.err("invalid hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn digits(&mut self) -> usize {
        let start = self.pos;
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        self.pos - start
    }

    /// Syntax-check a number token against the RFC 8259 grammar and emit
    /// it as a raw slice; the visitor chooses the numeric type to parse.
    // lint: hot-path
    fn number_event(&mut self) -> Result<()> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        if self.digits() == 0 {
            return Err(self.err("invalid number"));
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            if self.digits() == 0 {
                return Err(self.err("invalid number: empty fraction"));
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            if self.digits() == 0 {
                return Err(self.err("invalid number: empty exponent"));
            }
        }
        (self.visit)(Event::Num(&self.src[start..self.pos]))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

// ---------------- serving wire codec ----------------

/// Parse one request line `{"x":[...]}` (optional `"id":<u64>`, either
/// key order) into the reusable `x` buffer; returns the id. Strict by
/// design: unknown keys, duplicate keys, non-numeric features and
/// anything but a top-level object are errors, so client bugs surface as
/// error replies instead of silently skewed inputs.
// lint: hot-path
pub fn parse_request(lexer: &mut Lexer, line: &str, x: &mut Vec<f32>) -> Result<Option<u64>> {
    #[derive(Clone, Copy, PartialEq)]
    enum St {
        Start,
        Top,
        WantX,
        InX,
        WantId,
        Done,
    }
    x.clear();
    let mut id: Option<u64> = None;
    let mut saw_x = false;
    let mut st = St::Start;
    lexer.lex(line, &mut |ev| {
        match (st, ev) {
            (St::Start, Event::BeginObject) => st = St::Top,
            (St::Start, _) => return Err(Error::msg("request must be a JSON object")),
            (St::Top, Event::Key("x")) => {
                if saw_x {
                    return Err(Error::msg("request: duplicate key \"x\""));
                }
                st = St::WantX;
            }
            (St::Top, Event::Key("id")) => {
                if id.is_some() {
                    return Err(Error::msg("request: duplicate key \"id\""));
                }
                st = St::WantId;
            }
            (St::Top, Event::Key(k)) => {
                // lint: allow(hot-path-alloc) — cold path, malformed request
                return Err(Error::msg(format!("request: unknown key \"{k}\"")))
            }
            (St::Top, Event::EndObject) => st = St::Done,
            (St::WantX, Event::BeginArray) => st = St::InX,
            (St::WantX, _) => {
                return Err(Error::msg("request: \"x\" must be an array of numbers"))
            }
            (St::InX, Event::Num(s)) => x.push(parse_f32(s)?),
            (St::InX, Event::EndArray) => {
                saw_x = true;
                st = St::Top;
            }
            (St::InX, _) => {
                return Err(Error::msg("request: \"x\" must contain only numbers"))
            }
            (St::WantId, Event::Num(s)) => {
                id = Some(s.parse::<u64>().map_err(|_| {
                    // lint: allow(hot-path-alloc) — cold path, malformed request
                    Error::msg(format!("request: \"id\" must be an unsigned integer, got '{s}'"))
                })?);
                st = St::Top;
            }
            (St::WantId, _) => {
                return Err(Error::msg("request: \"id\" must be an unsigned integer"))
            }
            _ => return Err(Error::msg("request: unexpected structure")),
        }
        Ok(())
    })?;
    if !saw_x {
        return Err(Error::msg("request is missing \"x\""));
    }
    Ok(id)
}

/// Scalar fields of a parsed reply line (logits land in the caller's
/// buffer). `Copy`, so handing it around never allocates.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ReplyHead {
    pub id: Option<u64>,
    pub pred: Option<u64>,
    pub is_error: bool,
}

/// Client-side parse of one reply line into reusable buffers: on success
/// `logits` is filled; on an error reply `error` carries the message and
/// `is_error` is set. A `null` logit (the JSON spelling of a non-finite
/// float) decodes as NaN.
// lint: hot-path
pub fn parse_reply(
    lexer: &mut Lexer,
    line: &str,
    logits: &mut Vec<f32>,
    error: &mut String,
) -> Result<ReplyHead> {
    #[derive(Clone, Copy, PartialEq)]
    enum St {
        Start,
        Top,
        WantId,
        WantPred,
        WantLogits,
        InLogits,
        WantError,
        Done,
    }
    logits.clear();
    error.clear();
    let mut head = ReplyHead::default();
    let mut saw_logits = false;
    let mut st = St::Start;
    lexer.lex(line, &mut |ev| {
        match (st, ev) {
            (St::Start, Event::BeginObject) => st = St::Top,
            (St::Start, _) => return Err(Error::msg("reply must be a JSON object")),
            (St::Top, Event::Key("id")) => st = St::WantId,
            (St::Top, Event::Key("pred")) => st = St::WantPred,
            (St::Top, Event::Key("logits")) => st = St::WantLogits,
            (St::Top, Event::Key("error")) => st = St::WantError,
            (St::Top, Event::Key(k)) => {
                // lint: allow(hot-path-alloc) — cold path, malformed reply
                return Err(Error::msg(format!("reply: unknown key \"{k}\"")))
            }
            (St::Top, Event::EndObject) => st = St::Done,
            (St::WantId, Event::Num(s)) => {
                head.id = Some(s.parse::<u64>().map_err(|_| {
                    // lint: allow(hot-path-alloc) — cold path, malformed reply
                    Error::msg(format!("reply: bad id '{s}'"))
                })?);
                st = St::Top;
            }
            (St::WantPred, Event::Num(s)) => {
                head.pred = Some(s.parse::<u64>().map_err(|_| {
                    // lint: allow(hot-path-alloc) — cold path, malformed reply
                    Error::msg(format!("reply: bad pred '{s}'"))
                })?);
                st = St::Top;
            }
            (St::WantLogits, Event::BeginArray) => st = St::InLogits,
            (St::InLogits, Event::Num(s)) => logits.push(parse_f32(s)?),
            (St::InLogits, Event::Null) => logits.push(f32::NAN),
            (St::InLogits, Event::EndArray) => {
                saw_logits = true;
                st = St::Top;
            }
            (St::WantError, Event::Str(s)) => {
                error.push_str(s);
                head.is_error = true;
                st = St::Top;
            }
            _ => return Err(Error::msg("reply: unexpected structure")),
        }
        Ok(())
    })?;
    if !saw_logits && !head.is_error {
        return Err(Error::msg("reply has neither \"logits\" nor \"error\""));
    }
    Ok(head)
}

// lint: hot-path
fn parse_f32(s: &str) -> Result<f32> {
    s.parse::<f32>()
        // lint: allow(hot-path-alloc) — cold path, malformed number
        .map_err(|_| Error::msg(format!("bad number '{s}'")))
}

// lint: hot-path
fn push_f32(out: &mut String, v: f32) {
    if v.is_finite() {
        // shortest-round-trip Display: parses back to the same bits
        let _ = write!(out, "{v}");
    } else {
        out.push_str("null"); // JSON has no Inf/NaN
    }
}

// lint: hot-path
fn push_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Serialize a request line (client side) into `out` (cleared first),
/// trailing newline included.
// lint: hot-path
pub fn write_request(out: &mut String, id: Option<u64>, x: &[f32]) {
    out.clear();
    out.push('{');
    if let Some(id) = id {
        let _ = write!(out, "\"id\":{id},");
    }
    out.push_str("\"x\":[");
    for (i, &v) in x.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        push_f32(out, v);
    }
    out.push_str("]}\n");
}

/// Serialize a success reply into `out` (cleared first), trailing
/// newline included.
// lint: hot-path
pub fn write_reply(out: &mut String, id: Option<u64>, pred: usize, logits: &[f32]) {
    out.clear();
    out.push('{');
    if let Some(id) = id {
        let _ = write!(out, "\"id\":{id},");
    }
    let _ = write!(out, "\"pred\":{pred},\"logits\":[");
    for (i, &v) in logits.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        push_f32(out, v);
    }
    out.push_str("]}\n");
}

/// Serialize an error reply into `out` (cleared first), trailing newline
/// included.
// lint: hot-path
pub fn write_error(out: &mut String, id: Option<u64>, msg: &str) {
    out.clear();
    out.push('{');
    if let Some(id) = id {
        let _ = write!(out, "\"id\":{id},");
    }
    out.push_str("\"error\":");
    push_escaped(out, msg);
    out.push_str("}\n");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    /// Render the event stream as a compact trace for golden comparison.
    fn trace(src: &str) -> Result<Vec<String>> {
        let mut lx = Lexer::new();
        let mut out = Vec::new();
        lx.lex(src, &mut |ev| {
            out.push(match ev {
                Event::BeginObject => "{".into(),
                Event::EndObject => "}".into(),
                Event::BeginArray => "[".into(),
                Event::EndArray => "]".into(),
                Event::Key(k) => format!("k:{k}"),
                Event::Str(s) => format!("s:{s}"),
                Event::Num(n) => format!("n:{n}"),
                Event::Bool(b) => format!("b:{b}"),
                Event::Null => "null".into(),
            });
            Ok(())
        })?;
        Ok(out)
    }

    #[test]
    fn event_stream_of_nested_document() {
        let got = trace(r#" {"a": [1, -2.5e3, true, null], "b\n": "c\"d"} "#).unwrap();
        assert_eq!(
            got,
            vec![
                "{", "k:a", "[", "n:1", "n:-2.5e3", "b:true", "null", "]",
                "k:b\n", "s:c\"d", "}"
            ]
        );
    }

    #[test]
    fn rejects_malformed_documents() {
        for bad in [
            "", "{", "[1,", "{\"a\":}", "tru", "1.2.3", "\"\\x\"", "{}extra",
            "\"unterminated", "[1 2]", "{\"a\" 1}", "-", "1.", "1e", "01x",
            "[\"\u{1}\"]",
        ] {
            assert!(trace(bad).is_err(), "should reject {bad:?}");
        }
        // recursion guard, not a stack overflow
        let bomb = "[".repeat(4096);
        assert!(trace(&bomb).is_err());
    }

    #[test]
    fn visitor_error_aborts_the_walk() {
        let mut lx = Lexer::new();
        let mut seen = 0;
        let err = lx.lex("[1,2,3]", &mut |ev| {
            if matches!(ev, Event::Num("2")) {
                return Err(Error::msg("stop here"));
            }
            seen += 1;
            Ok(())
        });
        assert!(err.is_err());
        assert_eq!(seen, 2); // BeginArray + "1"
    }

    #[test]
    fn agrees_with_the_dom_parser_on_strings() {
        use crate::util::json::Value;
        // escaped + multibyte content decodes identically in both parsers
        let src = r#""a\n\t\"\\é😀 \u00e9 \ud83d\ude00""#;
        let want = Value::parse(src).unwrap().as_str().unwrap().to_string();
        let got = trace(src).unwrap();
        assert_eq!(got, vec![format!("s:{want}")]);
    }

    #[test]
    fn parse_request_happy_paths() {
        let mut lx = Lexer::new();
        let mut x = Vec::new();
        assert_eq!(parse_request(&mut lx, r#"{"x":[1,2.5,-3e-1]}"#, &mut x).unwrap(), None);
        assert_eq!(x, vec![1.0, 2.5, -0.3]);
        // both key orders, whitespace, empty array
        assert_eq!(
            parse_request(&mut lx, r#" {"id": 7, "x": [0.5]} "#, &mut x).unwrap(),
            Some(7)
        );
        assert_eq!(x, vec![0.5]);
        assert_eq!(
            parse_request(&mut lx, r#"{"x":[],"id":0}"#, &mut x).unwrap(),
            Some(0)
        );
        assert!(x.is_empty());
    }

    #[test]
    fn parse_request_is_strict() {
        let mut lx = Lexer::new();
        let mut x = Vec::new();
        for bad in [
            r#"[1,2]"#,                     // not an object
            r#"{"id":3}"#,                  // missing x
            r#"{"x":[1],"x":[2]}"#,         // duplicate x
            r#"{"x":[1],"y":2}"#,           // unknown key
            r#"{"x":[1],"id":-1}"#,         // negative id
            r#"{"x":[1],"id":1.5}"#,        // fractional id
            r#"{"x":[1,"a"]}"#,             // non-numeric feature
            r#"{"x":[null]}"#,              // null feature
            r#"{"x":[[1]]}"#,               // nested array
            r#"{"x":1}"#,                   // scalar x
            r#"{"x":[1]} {"x":[2]}"#,       // trailing data
        ] {
            assert!(parse_request(&mut lx, bad, &mut x).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn parse_request_reuses_buffers() {
        let mut lx = Lexer::new();
        let mut x = Vec::new();
        let line = r#"{"x":[1,2,3,4,5,6,7,8]}"#;
        parse_request(&mut lx, line, &mut x).unwrap();
        let cap = x.capacity();
        for _ in 0..16 {
            parse_request(&mut lx, line, &mut x).unwrap();
        }
        assert_eq!(x.capacity(), cap, "steady-state parse must not regrow");
        assert_eq!(x, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
    }

    #[test]
    fn request_round_trip_is_bit_exact() {
        let mut lx = Lexer::new();
        let mut line = String::new();
        let mut back = Vec::new();
        let mut rng = Pcg64::seed(42);
        for case in 0..200 {
            let n = 1 + (case % 17);
            let x: Vec<f32> = (0..n)
                .map(|_| {
                    // mix magnitudes: uniforms, tiny, huge, negatives
                    let u = rng.uniform() as f32;
                    let scale = match rng.next_u64() % 4 {
                        0 => 1.0,
                        1 => 1e-20,
                        2 => 1e20,
                        _ => -1.0,
                    };
                    u * scale
                })
                .collect();
            write_request(&mut line, Some(case as u64), &x);
            let id = parse_request(&mut lx, line.trim_end(), &mut back).unwrap();
            assert_eq!(id, Some(case as u64));
            assert_eq!(
                back.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                x.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "bits drifted for {x:?}"
            );
        }
    }

    #[test]
    fn reply_round_trip_and_error_replies() {
        let mut lx = Lexer::new();
        let mut line = String::new();
        let mut logits = Vec::new();
        let mut err = String::new();

        let want = [1.5f32, -0.25, 3.0e-8, 7.0];
        write_reply(&mut line, Some(9), 3, &want);
        assert!(line.ends_with("]}\n"), "{line}");
        let head = parse_reply(&mut lx, line.trim_end(), &mut logits, &mut err).unwrap();
        assert_eq!(head, ReplyHead { id: Some(9), pred: Some(3), is_error: false });
        assert_eq!(
            logits.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            want.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );

        // id-less replies (stdin-style clients) stay parseable
        write_reply(&mut line, None, 0, &[1.0]);
        let head = parse_reply(&mut lx, line.trim_end(), &mut logits, &mut err).unwrap();
        assert_eq!(head.id, None);

        // error replies escape the message and round-trip it
        let msg = "bad \"x\"\twidth\n(16 wanted)";
        write_error(&mut line, Some(4), msg);
        let head = parse_reply(&mut lx, line.trim_end(), &mut logits, &mut err).unwrap();
        assert!(head.is_error);
        assert_eq!(head.id, Some(4));
        assert_eq!(err, msg);
        assert!(logits.is_empty());

        // non-finite logits serialize as null and decode as NaN
        write_reply(&mut line, None, 0, &[f32::INFINITY, 1.0]);
        assert!(line.contains("null"), "{line}");
        parse_reply(&mut lx, line.trim_end(), &mut logits, &mut err).unwrap();
        assert!(logits[0].is_nan() && logits[1] == 1.0);

        // a reply with neither payload nor error is rejected
        assert!(parse_reply(&mut lx, r#"{"id":1}"#, &mut logits, &mut err).is_err());
    }
}
