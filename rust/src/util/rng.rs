//! Deterministic pseudo-random numbers: PCG64 + distribution helpers.
//!
//! Stands in for the `rand`/`rand_distr` crates. Every stochastic component
//! in the system — analog read-noise draws, weight initialisation, dataset
//! synthesis, shuffling — takes an explicit [`Pcg64`] so runs are exactly
//! reproducible from a single seed (recorded in each run's config.json).
//!
//! PCG-XSL-RR 128/64 (O'Neill 2014), the same generator `rand_pcg::Pcg64`
//! implements; constants from the reference implementation.

const MULTIPLIER: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

/// Serialised size of a [`Pcg64`] snapshot: 16-byte state, 16-byte
/// increment, 1-byte spare flag, 8-byte cached Gaussian variate.
pub const STATE_BYTES: usize = 41;

/// PCG-XSL-RR 128/64 generator.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
    /// cached second Box-Muller variate
    gauss_spare: Option<f64>,
}

impl Pcg64 {
    /// Seed with an arbitrary 64-bit seed and stream id. Distinct streams
    /// are statistically independent (different odd increments).
    pub fn new(seed: u64, stream: u64) -> Self {
        let initstate = (seed as u128) << 64 | splitmix64(seed) as u128;
        let initseq = (stream as u128) << 64 | splitmix64(stream ^ 0xda3e_39cb_94b9_5bdb) as u128;
        let mut rng = Pcg64 {
            state: 0,
            inc: (initseq << 1) | 1,
            gauss_spare: None,
        };
        rng.next_u64();
        rng.state = rng.state.wrapping_add(initstate);
        rng.next_u64();
        rng
    }

    /// Convenience single-stream constructor.
    pub fn seed(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Derive an independent child stream (for per-thread / per-purpose RNGs).
    pub fn fork(&mut self, stream: u64) -> Pcg64 {
        Pcg64::new(self.next_u64(), stream)
    }

    /// Counter-keyed stream: a generator that is a *pure function* of
    /// `(seed, step, lane)`. Unlike [`Self::fork`], no parent generator is
    /// consumed, so the stream a work item receives cannot depend on how
    /// work was scheduled — the property the photonic runtime uses to draw
    /// per-batch-row read noise that is bit-identical at any thread count.
    /// The `step` mixing is a splitmix64 round, so adjacent counters land
    /// on unrelated streams; `lane` selects the PCG stream (odd increment)
    /// directly. The domain constant keeps these streams disjoint from
    /// direct `Pcg64::new(seed, ...)` callers that share a seed.
    pub fn keyed(seed: u64, step: u64, lane: u64) -> Pcg64 {
        let mixed = splitmix64(seed ^ splitmix64(step ^ 0x6b69_7974_1e35_09d5));
        Pcg64::new(mixed, lane)
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(MULTIPLIER).wrapping_add(self.inc);
        // XSL-RR output function
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        // 53 random mantissa bits
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n). Unbiased (rejection sampling).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Standard normal via Box-Muller (with spare caching).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let theta = 2.0 * std::f64::consts::PI * u2;
            self.gauss_spare = Some(r * theta.sin());
            return r * theta.cos();
        }
    }

    /// Normal with given mean/std.
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gaussian()
    }

    /// Fill a f32 buffer with standard normal draws.
    pub fn fill_gaussian_f32(&mut self, buf: &mut [f32]) {
        for x in buf.iter_mut() {
            *x = self.gaussian() as f32;
        }
    }

    /// Fill a f32 buffer with U[lo, hi) draws.
    pub fn fill_uniform_f32(&mut self, buf: &mut [f32], lo: f32, hi: f32) {
        for x in buf.iter_mut() {
            *x = self.uniform_in(lo as f64, hi as f64) as f32;
        }
    }

    /// Snapshot the full generator state (checkpointing). Restoring with
    /// [`Self::from_state_bytes`] continues the exact same stream,
    /// including a cached Box-Muller spare.
    pub fn to_state_bytes(&self) -> [u8; STATE_BYTES] {
        let mut out = [0u8; STATE_BYTES];
        out[..16].copy_from_slice(&self.state.to_le_bytes());
        out[16..32].copy_from_slice(&self.inc.to_le_bytes());
        if let Some(z) = self.gauss_spare {
            out[32] = 1;
            out[33..41].copy_from_slice(&z.to_le_bytes());
        }
        out
    }

    /// Restore a generator saved with [`Self::to_state_bytes`]. Returns
    /// None for invalid snapshots (even increment, bad spare flag).
    pub fn from_state_bytes(bytes: &[u8; STATE_BYTES]) -> Option<Pcg64> {
        let state = u128::from_le_bytes(bytes[..16].try_into().unwrap());
        let inc = u128::from_le_bytes(bytes[16..32].try_into().unwrap());
        if inc & 1 == 0 || bytes[32] > 1 {
            return None; // PCG increments are always odd
        }
        let gauss_spare = (bytes[32] == 1)
            .then(|| f64::from_le_bytes(bytes[33..41].try_into().unwrap()));
        Some(Pcg64 { state, inc, gauss_spare })
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_seed_sensitive() {
        let draw = |seed: u64| -> Vec<u64> {
            let mut r = Pcg64::seed(seed);
            (0..8).map(move |_| r.next_u64()).collect()
        };
        let (a, b, c) = (draw(1), draw(1), draw(2));
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn streams_are_distinct() {
        let mut a = Pcg64::new(7, 0);
        let mut b = Pcg64::new(7, 1);
        let xs: Vec<u64> = (0..16).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..16).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Pcg64::seed(3);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Pcg64::seed(4);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
        // tails exist but are sane
        assert!(xs.iter().all(|x| x.abs() < 6.5));
        assert!(xs.iter().any(|x| x.abs() > 3.0));
    }

    #[test]
    fn below_is_unbiased_ish() {
        let mut r = Pcg64::seed(5);
        let mut counts = [0u32; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "{counts:?}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::seed(6);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn state_roundtrip_continues_stream() {
        let mut r = Pcg64::seed(11);
        let _ = r.gaussian(); // leave a cached spare behind
        let snap = r.to_state_bytes();
        let mut twin = Pcg64::from_state_bytes(&snap).unwrap();
        for _ in 0..8 {
            assert_eq!(r.next_u64(), twin.next_u64());
            assert_eq!(r.gaussian(), twin.gaussian());
        }
        // the restored snapshot itself re-serialises byte-identically
        assert_eq!(Pcg64::from_state_bytes(&snap).unwrap().to_state_bytes(), snap);
    }

    #[test]
    fn state_rejects_invalid_snapshots() {
        let mut snap = Pcg64::seed(1).to_state_bytes();
        snap[16] &= !1; // even increment
        assert!(Pcg64::from_state_bytes(&snap).is_none());
        let mut snap = Pcg64::seed(1).to_state_bytes();
        snap[32] = 7; // bad spare flag
        assert!(Pcg64::from_state_bytes(&snap).is_none());
    }

    #[test]
    fn fork_independent() {
        let mut root = Pcg64::seed(9);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn keyed_is_a_pure_function_of_the_triple() {
        let draw = |seed, step, lane| -> Vec<u64> {
            let mut r = Pcg64::keyed(seed, step, lane);
            (0..8).map(move |_| r.next_u64()).collect()
        };
        // same triple, same stream — regardless of construction order
        assert_eq!(draw(7, 3, 2), draw(7, 3, 2));
        let _unrelated = draw(99, 99, 99);
        assert_eq!(draw(7, 3, 2), draw(7, 3, 2));
        // every coordinate separates streams
        assert_ne!(draw(7, 3, 2), draw(8, 3, 2));
        assert_ne!(draw(7, 3, 2), draw(7, 4, 2));
        assert_ne!(draw(7, 3, 2), draw(7, 3, 3));
        // adjacent counters are unrelated, and keyed streams don't collide
        // with direct Pcg64::new streams of the same seed
        assert_ne!(draw(7, 0, 0), draw(7, 1, 0));
        let mut direct = Pcg64::new(7, 0);
        let direct: Vec<u64> = (0..8).map(|_| direct.next_u64()).collect();
        assert_ne!(draw(7, 0, 0), direct);
    }

    #[test]
    fn keyed_gaussian_spares_are_per_stream() {
        // fresh stream per (step, lane): the Box-Muller spare cached in one
        // stream can never leak into another work item's draws
        let mut a = Pcg64::keyed(5, 1, 0);
        let first = a.gaussian();
        let _ = a.gaussian(); // consume the spare
        let mut b = Pcg64::keyed(5, 1, 0);
        assert_eq!(b.gaussian(), first);
    }
}
