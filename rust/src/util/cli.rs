//! Declarative command-line argument parser (clap stand-in).
//!
//! Supports `--flag`, `--key value`, `--key=value`, typed accessors with
//! defaults, required arguments, and auto-generated `--help` text. Each
//! `pdfa` subcommand declares an [`ArgSpec`] list and gets validation for
//! free (unknown flags are rejected).

use std::collections::BTreeMap;

use crate::{Error, Result};

/// Declaration of one accepted argument.
#[derive(Debug, Clone)]
pub struct ArgSpec {
    pub name: &'static str,
    pub help: &'static str,
    /// None => boolean flag; Some(d) => takes a value with default `d`
    /// (empty default + required=true => must be provided).
    pub default: Option<&'static str>,
    pub required: bool,
}

impl ArgSpec {
    pub const fn flag(name: &'static str, help: &'static str) -> Self {
        ArgSpec { name, help, default: None, required: false }
    }

    pub const fn opt(name: &'static str, default: &'static str, help: &'static str) -> Self {
        ArgSpec { name, help, default: Some(default), required: false }
    }

    pub const fn req(name: &'static str, help: &'static str) -> Self {
        ArgSpec { name, help, default: Some(""), required: true }
    }
}

/// Parsed arguments for one (sub)command.
#[derive(Debug, Clone)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
}

impl Args {
    /// Parse `argv` (excluding the command name) against `specs`.
    pub fn parse(specs: &[ArgSpec], argv: &[String]) -> Result<Args> {
        let mut values = BTreeMap::new();
        let mut flags = BTreeMap::new();
        for s in specs {
            match s.default {
                None => {
                    flags.insert(s.name.to_string(), false);
                }
                Some(d) => {
                    values.insert(s.name.to_string(), d.to_string());
                }
            }
        }
        let mut provided: Vec<String> = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let arg = &argv[i];
            let stripped = arg
                .strip_prefix("--")
                .ok_or_else(|| Error::Cli(format!("unexpected positional argument '{arg}'")))?;
            let (key, inline_val) = match stripped.split_once('=') {
                Some((k, v)) => (k.to_string(), Some(v.to_string())),
                None => (stripped.to_string(), None),
            };
            let spec = specs
                .iter()
                .find(|s| s.name == key)
                .ok_or_else(|| Error::Cli(format!("unknown flag '--{key}'")))?;
            provided.push(key.clone());
            match spec.default {
                None => {
                    if inline_val.is_some() {
                        return Err(Error::Cli(format!("flag '--{key}' takes no value")));
                    }
                    flags.insert(key, true);
                }
                Some(_) => {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i).cloned().ok_or_else(|| {
                                Error::Cli(format!("flag '--{key}' expects a value"))
                            })?
                        }
                    };
                    values.insert(key, val);
                }
            }
            i += 1;
        }
        for s in specs {
            if s.required && !provided.iter().any(|p| p == s.name) {
                return Err(Error::Cli(format!("missing required flag '--{}'", s.name)));
            }
        }
        Ok(Args { values, flags })
    }

    pub fn flag(&self, name: &str) -> bool {
        *self.flags.get(name).unwrap_or(&false)
    }

    pub fn str(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("undeclared arg '{name}'"))
    }

    pub fn usize(&self, name: &str) -> Result<usize> {
        self.str(name).parse().map_err(|_| {
            Error::Cli(format!("--{name}: expected integer, got '{}'", self.str(name)))
        })
    }

    pub fn u64(&self, name: &str) -> Result<u64> {
        self.str(name).parse().map_err(|_| {
            Error::Cli(format!("--{name}: expected integer, got '{}'", self.str(name)))
        })
    }

    pub fn f64(&self, name: &str) -> Result<f64> {
        self.str(name)
            .parse()
            .map_err(|_| Error::Cli(format!("--{name}: expected number, got '{}'", self.str(name))))
    }

    /// Comma-separated list of floats (sweep specifications).
    pub fn f64_list(&self, name: &str) -> Result<Vec<f64>> {
        self.str(name)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| {
                s.trim()
                    .parse()
                    .map_err(|_| Error::Cli(format!("--{name}: bad list element '{s}'")))
            })
            .collect()
    }
}

/// Render `--help` text for a subcommand.
pub fn help_text(cmd: &str, about: &str, specs: &[ArgSpec]) -> String {
    let mut out = format!("{cmd} — {about}\n\nOptions:\n");
    for s in specs {
        let meta = match s.default {
            None => String::new(),
            Some("") if s.required => " <value> (required)".to_string(),
            Some(d) => format!(" <value> (default: {d})"),
        };
        out.push_str(&format!("  --{}{}\n      {}\n", s.name, meta, s.help));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<ArgSpec> {
        vec![
            ArgSpec::opt("epochs", "10", "number of epochs"),
            ArgSpec::opt("sigma", "0.0", "noise std"),
            ArgSpec::req("config", "network config"),
            ArgSpec::flag("verbose", "chatty output"),
        ]
    }

    fn s(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = Args::parse(&specs(), &s(&["--config", "tiny"])).unwrap();
        assert_eq!(a.usize("epochs").unwrap(), 10);
        assert_eq!(a.f64("sigma").unwrap(), 0.0);
        assert_eq!(a.str("config"), "tiny");
        assert!(!a.flag("verbose"));

        let a = Args::parse(
            &specs(),
            &s(&["--epochs=3", "--sigma", "0.098", "--config=mnist", "--verbose"]),
        )
        .unwrap();
        assert_eq!(a.usize("epochs").unwrap(), 3);
        assert_eq!(a.f64("sigma").unwrap(), 0.098);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn rejects_unknown_and_missing() {
        assert!(Args::parse(&specs(), &s(&["--config", "x", "--nope"])).is_err());
        assert!(Args::parse(&specs(), &s(&[])).is_err()); // missing required
        assert!(Args::parse(&specs(), &s(&["--config"])).is_err()); // dangling
        assert!(Args::parse(&specs(), &s(&["positional"])).is_err());
        assert!(Args::parse(&specs(), &s(&["--verbose=1", "--config", "x"])).is_err());
    }

    #[test]
    fn lists() {
        let sp = vec![ArgSpec::opt("bits", "1,2,3", "sweep")];
        let a = Args::parse(&sp, &s(&["--bits", "1.5, 2.5,4"])).unwrap();
        assert_eq!(a.f64_list("bits").unwrap(), vec![1.5, 2.5, 4.0]);
    }

    #[test]
    fn bad_types_error() {
        let a = Args::parse(&specs(), &s(&["--config", "x", "--epochs", "abc"])).unwrap();
        assert!(a.usize("epochs").is_err());
    }

    #[test]
    fn help_mentions_flags() {
        let h = help_text("train", "train a network", &specs());
        assert!(h.contains("--epochs"));
        assert!(h.contains("(required)"));
    }
}
