//! Lightweight property-based testing harness (proptest stand-in).
//!
//! `check(name, cases, |rng| ...)` runs a property against `cases` random
//! inputs drawn through a seeded [`Pcg64`]; on failure it reports the case
//! index and the per-case seed so the exact failing input can be replayed
//! with [`replay`]. Deliberately simple: no shrinking, but deterministic
//! reproduction, which is what matters for CI.

use super::rng::Pcg64;

/// Outcome of a single property case.
pub type CaseResult = std::result::Result<(), String>;

/// Run `prop` against `cases` independently-seeded RNGs. Panics with a
/// replayable seed on the first failure.
pub fn check<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Pcg64) -> CaseResult,
{
    let base = fnv1a(name.as_bytes());
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = Pcg64::seed(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed on case {case}/{cases} \
                 (replay with util::check::replay(\"{name}\", {case}, prop)): {msg}"
            );
        }
    }
}

/// Re-run a single failing case of `check` by name + case index.
pub fn replay<F>(name: &str, case: u64, mut prop: F) -> CaseResult
where
    F: FnMut(&mut Pcg64) -> CaseResult,
{
    let base = fnv1a(name.as_bytes());
    let seed = base.wrapping_add(case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    prop(&mut Pcg64::seed(seed))
}

/// Assert two f32 slices agree elementwise within `atol`. A NaN on
/// either side fails the comparison (a silently-passing NaN is how a
/// poisoned kernel output slips through a tolerance check).
pub fn assert_close(got: &[f32], want: &[f32], atol: f32) -> CaseResult {
    if got.len() != want.len() {
        return Err(format!("length mismatch: {} vs {}", got.len(), want.len()));
    }
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        // negated <= so a NaN diff (NaN on either side) fails, rather
        // than sailing through an always-false `> atol`
        if !((g - w).abs() <= atol) {
            return Err(format!(
                "element {i}: got {g}, want {w} (|diff| {} > atol {atol})",
                (g - w).abs()
            ));
        }
    }
    Ok(())
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut ran = 0;
        check("always-true", 32, |rng| {
            ran += 1;
            let x = rng.uniform();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
        assert_eq!(ran, 32);
    }

    #[test]
    #[should_panic(expected = "property 'always-false' failed")]
    fn failing_property_panics_with_context() {
        check("always-false", 8, |_| Err("nope".into()));
    }

    #[test]
    fn replay_reproduces_case_input() {
        let mut first: Vec<u64> = Vec::new();
        check("record", 4, |rng| {
            first.push(rng.next_u64());
            Ok(())
        });
        for (case, want) in first.iter().enumerate() {
            replay("record", case as u64, |rng| {
                assert_eq!(rng.next_u64(), *want);
                Ok(())
            })
            .unwrap();
        }
    }

    #[test]
    fn assert_close_detects_mismatch() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.0], 1e-6).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-3).is_err());
        assert!(assert_close(&[1.0], &[1.0, 2.0], 1e-3).is_err());
    }

    #[test]
    fn assert_close_boundary_and_empty() {
        // exactly-atol differences pass (<=, not <)
        assert!(assert_close(&[1.0], &[1.5], 0.5).is_ok());
        assert!(assert_close(&[1.0], &[1.5], 0.49).is_err());
        // empty slices trivially agree
        assert!(assert_close(&[], &[], 0.0).is_ok());
    }

    #[test]
    fn assert_close_rejects_nan_on_either_side() {
        assert!(assert_close(&[f32::NAN], &[1.0], 1e9).is_err());
        assert!(assert_close(&[1.0], &[f32::NAN], 1e9).is_err());
        assert!(assert_close(&[f32::NAN], &[f32::NAN], 1e9).is_err());
        // infinities behave like ordinary out-of-tolerance values
        assert!(assert_close(&[f32::INFINITY], &[1.0], 1e9).is_err());
    }

    #[test]
    fn distinct_property_names_draw_distinct_streams() {
        let mut a = Vec::new();
        let mut b = Vec::new();
        check("stream-a", 4, |rng| {
            a.push(rng.next_u64());
            Ok(())
        });
        check("stream-b", 4, |rng| {
            b.push(rng.next_u64());
            Ok(())
        });
        assert_ne!(a, b, "independently named properties must not share inputs");
        // and re-running the same name reproduces the same inputs
        let mut a2 = Vec::new();
        check("stream-a", 4, |rng| {
            a2.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(a, a2);
    }

    #[test]
    fn check_reports_case_index_in_panic_message() {
        let caught = std::panic::catch_unwind(|| {
            let mut n = 0u64;
            check("fail-on-third", 8, move |_| {
                n += 1;
                if n == 3 {
                    Err("third case".into())
                } else {
                    Ok(())
                }
            });
        });
        let err = caught.expect_err("property must fail");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_default();
        assert!(msg.contains("failed on case 2/8"), "got: {msg}");
        assert!(msg.contains("replay"), "got: {msg}");
    }
}
