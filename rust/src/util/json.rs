//! Minimal JSON parser and serializer.
//!
//! Stands in for `serde_json` (unavailable offline). Supports the full JSON
//! grammar (RFC 8259): objects, arrays, strings with escapes (including
//! `\uXXXX` surrogate pairs), numbers, booleans, null. Used for the AOT
//! artifact manifest, run configuration files and experiment reports.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::{Error, Result};

/// A parsed JSON value. Object keys are sorted (BTreeMap) so serialization
/// is deterministic — handy for golden tests and reproducible reports.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<Value>),
    Object(BTreeMap<String, Value>),
}

impl Value {
    pub fn parse(text: &str) -> Result<Value> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data after JSON value"));
        }
        Ok(v)
    }

    // -------- typed accessors --------

    pub fn as_object(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Object(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::String(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Number(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|f| {
            if f >= 0.0 && f.fract() == 0.0 {
                Some(f as usize)
            } else {
                None
            }
        })
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// `obj["a"]["b"]`-style access; returns Null for missing keys.
    pub fn get(&self, key: &str) -> &Value {
        static NULL: Value = Value::Null;
        self.as_object().and_then(|m| m.get(key)).unwrap_or(&NULL)
    }

    /// Typed field lookup with a useful error message.
    pub fn require(&self, key: &str) -> Result<&Value> {
        self.as_object()
            .and_then(|m| m.get(key))
            .ok_or_else(|| Error::Manifest(format!("missing key '{key}'")))
    }

    // -------- construction helpers --------

    pub fn object(pairs: Vec<(&str, Value)>) -> Value {
        Value::Object(
            pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect(),
        )
    }

    pub fn array_f64(xs: &[f64]) -> Value {
        Value::Array(xs.iter().map(|&x| Value::Number(x)).collect())
    }

    pub fn str(s: impl Into<String>) -> Value {
        Value::String(s.into())
    }

    // -------- serialization --------

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push(' ');
                }
            }
        };
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Number(n) => write_number(out, *n),
            Value::String(s) => write_string(out, s),
            Value::Array(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    item.write(out, indent + 1, pretty);
                }
                pad(out, indent);
                out.push(']');
            }
            Value::Object(map) => {
                if map.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_string(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                pad(out, indent);
                out.push('}');
            }
        }
    }
}

fn write_number(out: &mut String, n: f64) {
    if n == 0.0 && n.is_sign_negative() {
        // the integer fast path would cast -0.0 to 0 and drop the sign;
        // "-0" parses back to -0.0, keeping round-trips bit-exact
        out.push_str("-0");
    } else if n.is_finite() && n.fract() == 0.0 && n.abs() < 1e15 {
        let _ = write!(out, "{}", n as i64);
    } else if n.is_finite() {
        let _ = write!(out, "{n}");
    } else {
        out.push_str("null"); // JSON has no Inf/NaN
    }
}

fn write_string(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: impl Into<String>) -> Error {
        Error::Json { offset: self.pos, msg: msg.into() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect_byte(&mut self, b: u8) -> Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Value) -> Result<Value> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(format!("invalid literal, expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Value> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::String(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(c) => Err(self.err(format!("unexpected byte '{}'", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn object(&mut self) -> Result<Value> {
        self.expect_byte(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect_byte(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Object(map)),
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Value> {
        self.expect_byte(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Array(items)),
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect_byte(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000C}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            // high surrogate: must be followed by \uXXXX low
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("expected low surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c)
                        } else {
                            char::from_u32(cp)
                        };
                        s.push(c.ok_or_else(|| self.err("invalid codepoint"))?);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(b) if b < 0x20 => {
                    return Err(self.err("control character in string"))
                }
                Some(b) => {
                    // Re-assemble UTF-8 multibyte sequences verbatim.
                    let len = utf8_len(b);
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump();
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("eof in \\u escape"))?;
            let d = (b as char)
                .to_digit(16)
                .ok_or_else(|| self.err("invalid hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Number)
            .map_err(|_| self.err(format!("invalid number '{text}'")))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Value::parse("null").unwrap(), Value::Null);
        assert_eq!(Value::parse("true").unwrap(), Value::Bool(true));
        assert_eq!(Value::parse(" false ").unwrap(), Value::Bool(false));
        assert_eq!(Value::parse("42").unwrap(), Value::Number(42.0));
        assert_eq!(Value::parse("-1.5e3").unwrap(), Value::Number(-1500.0));
        assert_eq!(
            Value::parse("\"hi\"").unwrap(),
            Value::String("hi".into())
        );
    }

    #[test]
    fn parses_nested() {
        let v = Value::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").as_array().unwrap().len(), 3);
        assert_eq!(v.get("a").as_array().unwrap()[0].as_f64(), Some(1.0));
        assert_eq!(v.get("c").as_str(), Some("x"));
        assert_eq!(*v.get("a").as_array().unwrap()[2].get("b"), Value::Null);
    }

    #[test]
    fn parses_escapes_and_unicode() {
        let v = Value::parse(r#""a\n\t\"\\é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\n\t\"\\é😀");
        // non-escaped multibyte passthrough
        let v = Value::parse("\"héllo — ok\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo — ok");
    }

    #[test]
    fn rejects_malformed() {
        for bad in [
            "", "{", "[1,", "{\"a\":}", "tru", "1.2.3", "\"\\x\"", "{}extra",
            "\"unterminated", "[1 2]",
        ] {
            assert!(Value::parse(bad).is_err(), "should reject {bad:?}");
        }
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,-3],"nested":{"s":"v\"q","t":true},"z":null}"#;
        let v = Value::parse(src).unwrap();
        let ser = v.to_string_compact();
        assert_eq!(Value::parse(&ser).unwrap(), v);
        let pretty = v.to_string_pretty();
        assert_eq!(Value::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn real_manifest_shape() {
        let src = r#"{"artifacts": {"fwd_tiny": {"file": "fwd_tiny.hlo.txt",
            "inputs": [{"name": "w1", "shape": [16, 32], "dtype": "f32"}]}},
            "format": 1}"#;
        let v = Value::parse(src).unwrap();
        assert_eq!(v.get("format").as_usize(), Some(1));
        let inp = &v.get("artifacts").get("fwd_tiny").get("inputs").as_array().unwrap()[0];
        assert_eq!(inp.get("name").as_str(), Some("w1"));
        let shape: Vec<usize> = inp
            .get("shape")
            .as_array()
            .unwrap()
            .iter()
            .map(|s| s.as_usize().unwrap())
            .collect();
        assert_eq!(shape, vec![16, 32]);
    }

    #[test]
    fn number_formatting() {
        assert_eq!(Value::Number(3.0).to_string_compact(), "3");
        assert_eq!(Value::Number(0.25).to_string_compact(), "0.25");
        assert_eq!(Value::Number(f64::NAN).to_string_compact(), "null");
        // regression: the i64 fast path cast -0.0 to "0", losing the sign
        assert_eq!(Value::Number(-0.0).to_string_compact(), "-0");
    }

    #[test]
    fn number_round_trip_preserves_bits() {
        use crate::util::rng::Pcg64;
        // `Value::PartialEq` can't see this drift (-0.0 == 0.0 under f64
        // equality), so compare raw bit patterns
        let check = |v: f64| {
            let ser = Value::Number(v).to_string_compact();
            let back = Value::parse(&ser).unwrap().as_f64().unwrap();
            assert_eq!(back.to_bits(), v.to_bits(), "{v:?} -> {ser} -> {back:?}");
        };
        // sign, subnormal and i64-cast-boundary edges, explicitly
        for v in [
            0.0,
            -0.0,
            5e-324,  // smallest positive subnormal
            -5e-324,
            f64::MIN_POSITIVE,
            f64::MAX,
            f64::MIN,
            f64::EPSILON,
            1e15,     // first value routed to the float branch
            -1e15,
            1e15 - 1.0, // last value through the integer fast path
            999999999999999.5,
            9007199254740992.0, // 2^53: integral but above 1e15
            1.0 / 3.0,
        ] {
            check(v);
        }
        // randomized sweep over raw bit patterns: hits subnormals, huge
        // exponents and long mantissas the handpicked list can't
        let mut rng = Pcg64::seed(2026);
        let mut tested = 0;
        while tested < 4000 {
            let v = f64::from_bits(rng.next_u64());
            if !v.is_finite() {
                continue; // NaN/inf serialize as null by design
            }
            check(v);
            tested += 1;
        }
    }
}
