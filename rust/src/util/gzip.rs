//! Self-contained gzip (RFC 1952) + DEFLATE (RFC 1951) codec.
//!
//! Stands in for `flate2` (unavailable offline). The decoder implements
//! full inflate — stored, fixed-Huffman and dynamic-Huffman blocks — so
//! real gzipped MNIST files load; the encoder emits stored (uncompressed)
//! deflate blocks, which every standard tool decompresses. Both ends
//! carry the CRC-32 / ISIZE trailer.

use crate::{Error, Result};

const MAX_BITS: usize = 15;

fn err(msg: impl Into<String>) -> Error {
    Error::Data(format!("gzip: {}", msg.into()))
}

// ---------------- CRC-32 (IEEE, reflected) ----------------

const CRC_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut n = 0;
    while n < 256 {
        let mut c = n as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xedb8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[n] = c;
        n += 1;
    }
    table
};

/// CRC-32 of `data` (the gzip trailer checksum).
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = 0xffff_ffffu32;
    for &b in data {
        c = CRC_TABLE[((c ^ b as u32) & 0xff) as usize] ^ (c >> 8);
    }
    c ^ 0xffff_ffff
}

// ---------------- compression (stored blocks) ----------------

/// Wrap `data` in a valid gzip stream using stored deflate blocks.
///
/// No compression is attempted — IDX payloads are consumed locally and
/// the format only needs to round-trip — but the output is standard gzip
/// that `gunzip`/`flate2`/`zlib` all accept.
pub fn compress(data: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(data.len() + 64);
    // header: magic, CM=deflate, no flags, mtime 0, XFL 0, OS unknown
    out.extend_from_slice(&[0x1f, 0x8b, 0x08, 0x00, 0, 0, 0, 0, 0x00, 0xff]);
    let mut chunks = data.chunks(0xffff).peekable();
    if data.is_empty() {
        out.extend_from_slice(&[0x01, 0x00, 0x00, 0xff, 0xff]); // final empty block
    }
    while let Some(chunk) = chunks.next() {
        let bfinal = if chunks.peek().is_none() { 1u8 } else { 0 };
        out.push(bfinal); // BTYPE=00 (stored), byte-aligned
        let len = chunk.len() as u16;
        out.extend_from_slice(&len.to_le_bytes());
        out.extend_from_slice(&(!len).to_le_bytes());
        out.extend_from_slice(chunk);
    }
    out.extend_from_slice(&crc32(data).to_le_bytes());
    out.extend_from_slice(&(data.len() as u32).to_le_bytes());
    out
}

// ---------------- decompression ----------------

/// LSB-first bit reader over the deflate payload.
struct BitReader<'a> {
    data: &'a [u8],
    pos: usize,
    acc: u32,
    nbits: u32,
}

impl<'a> BitReader<'a> {
    fn new(data: &'a [u8]) -> BitReader<'a> {
        BitReader { data, pos: 0, acc: 0, nbits: 0 }
    }

    fn bits(&mut self, n: u32) -> Result<u32> {
        while self.nbits < n {
            let byte = *self
                .data
                .get(self.pos)
                .ok_or_else(|| err("truncated deflate stream"))?;
            self.acc |= (byte as u32) << self.nbits;
            self.nbits += 8;
            self.pos += 1;
        }
        let v = self.acc & ((1u32 << n) - 1);
        self.acc >>= n;
        self.nbits -= n;
        Ok(v)
    }

    /// Discard buffered bits so the cursor is byte-aligned (stored blocks).
    fn align(&mut self) {
        self.acc = 0;
        self.nbits = 0;
    }
}

/// A canonical Huffman decoding table: symbol counts and the symbols
/// sorted by (code length, symbol) — the puff.c representation.
struct Huffman {
    count: [u16; MAX_BITS + 1],
    symbols: Vec<u16>,
}

impl Huffman {
    /// Build from per-symbol code lengths (0 = unused).
    fn new(lengths: &[u8]) -> Result<Huffman> {
        let mut count = [0u16; MAX_BITS + 1];
        for &l in lengths {
            if l as usize > MAX_BITS {
                return Err(err("code length exceeds 15"));
            }
            count[l as usize] += 1;
        }
        count[0] = 0;
        // over-subscribed codes are invalid
        let mut left = 1i32;
        for l in 1..=MAX_BITS {
            left = (left << 1) - count[l] as i32;
            if left < 0 {
                return Err(err("over-subscribed Huffman code"));
            }
        }
        let mut offsets = [0u16; MAX_BITS + 2];
        for l in 1..=MAX_BITS {
            offsets[l + 1] = offsets[l] + count[l];
        }
        let mut symbols = vec![0u16; lengths.iter().filter(|&&l| l != 0).count()];
        for (sym, &l) in lengths.iter().enumerate() {
            if l != 0 {
                symbols[offsets[l as usize] as usize] = sym as u16;
                offsets[l as usize] += 1;
            }
        }
        Ok(Huffman { count, symbols })
    }

    fn decode(&self, r: &mut BitReader) -> Result<u16> {
        let mut code = 0i32;
        let mut first = 0i32;
        let mut index = 0i32;
        for len in 1..=MAX_BITS {
            code |= r.bits(1)? as i32;
            let cnt = self.count[len] as i32;
            if code - first < cnt {
                return Ok(self.symbols[(index + (code - first)) as usize]);
            }
            index += cnt;
            first = (first + cnt) << 1;
            code <<= 1;
        }
        Err(err("invalid Huffman code"))
    }
}

const LENGTH_BASE: [u16; 29] = [
    3, 4, 5, 6, 7, 8, 9, 10, 11, 13, 15, 17, 19, 23, 27, 31, 35, 43, 51, 59,
    67, 83, 99, 115, 131, 163, 195, 227, 258,
];
const LENGTH_EXTRA: [u8; 29] = [
    0, 0, 0, 0, 0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2, 3, 3, 3, 3, 4, 4, 4, 4,
    5, 5, 5, 5, 0,
];
const DIST_BASE: [u16; 30] = [
    1, 2, 3, 4, 5, 7, 9, 13, 17, 25, 33, 49, 65, 97, 129, 193, 257, 385, 513,
    769, 1025, 1537, 2049, 3073, 4097, 6145, 8193, 12289, 16385, 24577,
];
const DIST_EXTRA: [u8; 30] = [
    0, 0, 0, 0, 1, 1, 2, 2, 3, 3, 4, 4, 5, 5, 6, 6, 7, 7, 8, 8, 9, 9, 10, 10,
    11, 11, 12, 12, 13, 13,
];

/// Decode one Huffman-coded block body into `out`.
fn inflate_block(
    r: &mut BitReader,
    litlen: &Huffman,
    dist: &Huffman,
    out: &mut Vec<u8>,
) -> Result<()> {
    loop {
        let sym = litlen.decode(r)?;
        match sym {
            0..=255 => out.push(sym as u8),
            256 => return Ok(()),
            257..=285 => {
                let i = (sym - 257) as usize;
                let len =
                    LENGTH_BASE[i] as usize + r.bits(LENGTH_EXTRA[i] as u32)? as usize;
                let dsym = dist.decode(r)? as usize;
                if dsym >= 30 {
                    return Err(err("invalid distance symbol"));
                }
                let d = DIST_BASE[dsym] as usize + r.bits(DIST_EXTRA[dsym] as u32)? as usize;
                if d > out.len() {
                    return Err(err("distance beyond output start"));
                }
                let start = out.len() - d;
                for k in 0..len {
                    let b = out[start + k];
                    out.push(b); // byte-wise: distances may overlap the copy
                }
            }
            _ => return Err(err("invalid literal/length symbol")),
        }
    }
}

fn fixed_tables() -> (Huffman, Huffman) {
    let mut litlen = [0u8; 288];
    litlen[..144].fill(8);
    litlen[144..256].fill(9);
    litlen[256..280].fill(7);
    litlen[280..].fill(8);
    let dist = [5u8; 30];
    (
        Huffman::new(&litlen).expect("fixed litlen table is valid"),
        Huffman::new(&dist).expect("fixed dist table is valid"),
    )
}

/// Order in which the code-length code's lengths are transmitted.
const CLC_ORDER: [usize; 19] = [
    16, 17, 18, 0, 8, 7, 9, 6, 10, 5, 11, 4, 12, 3, 13, 2, 14, 1, 15,
];

fn dynamic_tables(r: &mut BitReader) -> Result<(Huffman, Huffman)> {
    let hlit = r.bits(5)? as usize + 257;
    let hdist = r.bits(5)? as usize + 1;
    let hclen = r.bits(4)? as usize + 4;
    if hlit > 286 || hdist > 30 {
        return Err(err("bad dynamic table counts"));
    }
    let mut clc_lengths = [0u8; 19];
    for &slot in CLC_ORDER.iter().take(hclen) {
        clc_lengths[slot] = r.bits(3)? as u8;
    }
    let clc = Huffman::new(&clc_lengths)?;

    let mut lengths = vec![0u8; hlit + hdist];
    let mut i = 0;
    while i < lengths.len() {
        let sym = clc.decode(r)?;
        match sym {
            0..=15 => {
                lengths[i] = sym as u8;
                i += 1;
            }
            16 => {
                if i == 0 {
                    return Err(err("repeat with no previous length"));
                }
                let prev = lengths[i - 1];
                let n = 3 + r.bits(2)? as usize;
                for _ in 0..n {
                    if i >= lengths.len() {
                        return Err(err("length repeat overflows table"));
                    }
                    lengths[i] = prev;
                    i += 1;
                }
            }
            17 | 18 => {
                let n = if sym == 17 {
                    3 + r.bits(3)? as usize
                } else {
                    11 + r.bits(7)? as usize
                };
                if i + n > lengths.len() {
                    return Err(err("zero-run overflows table"));
                }
                i += n; // already zero-initialised
            }
            _ => return Err(err("invalid code-length symbol")),
        }
    }
    if lengths[256] == 0 {
        return Err(err("dynamic table has no end-of-block code"));
    }
    Ok((
        Huffman::new(&lengths[..hlit])?,
        Huffman::new(&lengths[hlit..])?,
    ))
}

/// Raw DEFLATE decode (no gzip framing).
pub fn inflate(data: &[u8]) -> Result<Vec<u8>> {
    let mut r = BitReader::new(data);
    let mut out = Vec::with_capacity(data.len() * 3);
    loop {
        let bfinal = r.bits(1)?;
        match r.bits(2)? {
            0 => {
                r.align();
                let need = |p: usize| -> Result<u8> {
                    data.get(p).copied().ok_or_else(|| err("truncated stored block"))
                };
                let len =
                    u16::from_le_bytes([need(r.pos)?, need(r.pos + 1)?]) as usize;
                let nlen =
                    u16::from_le_bytes([need(r.pos + 2)?, need(r.pos + 3)?]) as usize;
                if len != (!nlen & 0xffff) {
                    return Err(err("stored block LEN/NLEN mismatch"));
                }
                let start = r.pos + 4;
                if start + len > data.len() {
                    return Err(err("truncated stored block payload"));
                }
                out.extend_from_slice(&data[start..start + len]);
                r.pos = start + len;
            }
            1 => {
                let (litlen, dist) = fixed_tables();
                inflate_block(&mut r, &litlen, &dist, &mut out)?;
            }
            2 => {
                let (litlen, dist) = dynamic_tables(&mut r)?;
                inflate_block(&mut r, &litlen, &dist, &mut out)?;
            }
            _ => return Err(err("reserved block type")),
        }
        if bfinal != 0 {
            return Ok(out);
        }
    }
}

/// Decompress a full gzip stream, verifying the CRC-32/ISIZE trailer.
pub fn decompress(gz: &[u8]) -> Result<Vec<u8>> {
    if gz.len() < 18 {
        return Err(err("stream shorter than header + trailer"));
    }
    if gz[0] != 0x1f || gz[1] != 0x8b {
        return Err(err("bad magic bytes"));
    }
    if gz[2] != 0x08 {
        return Err(err(format!("unsupported compression method {}", gz[2])));
    }
    let flg = gz[3];
    if flg & 0xe0 != 0 {
        return Err(err("reserved header flags set"));
    }
    let mut pos = 10usize;
    if flg & 0x04 != 0 {
        // FEXTRA
        if pos + 2 > gz.len() {
            return Err(err("truncated FEXTRA"));
        }
        let xlen = u16::from_le_bytes([gz[pos], gz[pos + 1]]) as usize;
        pos += 2 + xlen;
    }
    for flag in [0x08u8, 0x10] {
        // FNAME, FCOMMENT: zero-terminated
        if flg & flag != 0 {
            let end = gz[pos.min(gz.len())..]
                .iter()
                .position(|&b| b == 0)
                .ok_or_else(|| err("unterminated header string"))?;
            pos += end + 1;
        }
    }
    if flg & 0x02 != 0 {
        pos += 2; // FHCRC
    }
    if pos + 8 > gz.len() {
        return Err(err("truncated after header"));
    }
    let payload = &gz[pos..gz.len() - 8];
    let out = inflate(payload)?;
    let trailer = &gz[gz.len() - 8..];
    let want_crc = u32::from_le_bytes([trailer[0], trailer[1], trailer[2], trailer[3]]);
    let want_len = u32::from_le_bytes([trailer[4], trailer[5], trailer[6], trailer[7]]);
    if crc32(&out) != want_crc {
        return Err(err("CRC-32 mismatch"));
    }
    if out.len() as u32 != want_len {
        return Err(err("ISIZE mismatch"));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn crc32_known_vectors() {
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xcbf4_3926);
        assert_eq!(crc32(b"photonic"), 0xc553_5688);
    }

    #[test]
    fn stored_roundtrip() {
        for n in [0usize, 1, 100, 0xffff, 0xffff + 1, 200_000] {
            let mut rng = Pcg64::seed(n as u64);
            let data: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
            let gz = compress(&data);
            assert_eq!(decompress(&gz).unwrap(), data, "n={n}");
        }
    }

    #[test]
    fn decodes_zlib_fixed_huffman_stream() {
        // python3: gzip.compress(b"photonic", mtime=0)
        let gz: &[u8] = &[
            31, 139, 8, 0, 0, 0, 0, 0, 2, 255, 43, 200, 200, 47, 201, 207,
            203, 76, 6, 0, 136, 86, 83, 197, 8, 0, 0, 0,
        ];
        assert_eq!(decompress(gz).unwrap(), b"photonic");
    }

    #[test]
    fn decodes_zlib_compressed_stream_with_back_references() {
        // python3: gzip.compress(b"direct feedback alignment " * 12,
        //          compresslevel=9, mtime=0) — 312 bytes -> 51
        let gz: &[u8] = &[
            31, 139, 8, 0, 0, 0, 0, 0, 2, 255, 75, 201, 44, 74, 77, 46, 81,
            72, 75, 77, 77, 73, 74, 76, 206, 86, 72, 204, 201, 76, 207, 203,
            77, 205, 43, 81, 72, 25, 149, 193, 35, 3, 0, 26, 103, 76, 99, 56,
            1, 0, 0,
        ];
        let want: Vec<u8> = b"direct feedback alignment ".repeat(12);
        assert_eq!(decompress(gz).unwrap(), want);
    }

    #[test]
    fn rejects_malformed_streams() {
        let good = compress(b"payload");
        // bad magic
        let mut bad = good.clone();
        bad[0] = 0x1e;
        assert!(decompress(&bad).is_err());
        // bad method
        let mut bad = good.clone();
        bad[2] = 0x07;
        assert!(decompress(&bad).is_err());
        // corrupted payload -> CRC mismatch
        let mut bad = good.clone();
        let mid = bad.len() - 10;
        bad[mid] ^= 0xff;
        assert!(decompress(&bad).is_err());
        // truncation at every prefix must error, never panic
        for cut in 0..good.len() {
            assert!(decompress(&good[..cut]).is_err(), "cut={cut}");
        }
        assert!(inflate(&[]).is_err());
    }

    #[test]
    fn compressed_output_is_framed_gzip() {
        let gz = compress(b"abc");
        assert_eq!(&gz[..3], &[0x1f, 0x8b, 0x08]);
        // stored block: BFINAL=1/BTYPE=00, LEN=3, NLEN=~3
        assert_eq!(gz[10], 0x01);
        assert_eq!(&gz[11..15], &[3, 0, 0xfc, 0xff]);
        assert_eq!(&gz[15..18], b"abc");
    }
}
