//! Thread-count resolution shared by every parallel subsystem.
//!
//! One convention, everywhere a `--threads`/`threads` knob appears
//! (tensor kernels, the photonic row shards, the physics-sweep grid,
//! dataset synthesis): `0` means "use every core the OS grants us",
//! any other value is taken literally. Centralising the
//! `available_parallelism` fallback keeps the CLI default and the
//! library defaults in lockstep — and because every parallel path in
//! this crate is bit-deterministic by construction, the resolved value
//! only ever changes wall-clock time, never results.

/// Cores the OS reports as available (>= 1; single-core fallback when
/// the query fails, e.g. in restricted sandboxes).
pub fn available() -> usize {
    std::thread::available_parallelism()
        .map(|t| t.get())
        .unwrap_or(1)
}

/// Resolve a user-facing thread knob: `0` = [`available`], otherwise the
/// literal request (callers cap it against their own work-item count).
pub fn resolve(threads: usize) -> usize {
    if threads == 0 {
        available()
    } else {
        threads
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolve_semantics() {
        assert!(available() >= 1);
        assert_eq!(resolve(0), available());
        assert_eq!(resolve(1), 1);
        assert_eq!(resolve(7), 7);
    }
}
