//! Hand-rolled infrastructure substrates.
//!
//! The offline vendor set lacks `serde_json`, `rand`, `clap`, `criterion`,
//! `proptest`, `flate2` and the `log` facade, so this module provides the
//! pieces the rest of the crate needs, each small, documented and
//! unit-tested:
//!
//! * [`json`]   — JSON parser/serializer (artifact manifest, run configs)
//! * [`json_stream`] — visiting JSON lexer + zero-allocation NDJSON codec
//!   for the serving hot path (requests into reusable buffers, no DOM)
//! * [`rng`]    — PCG64 RNG + Gaussian/uniform draws (noise sampling, init)
//! * [`stats`]  — mean/std/percentiles, effective-resolution, correlation
//! * [`cli`]    — declarative argument parser for the `pdfa` binary
//! * [`check`]  — lightweight property-testing harness (proptest stand-in)
//! * [`benchx`] — micro-benchmark harness (criterion stand-in)
//! * [`gzip`]   — gzip/DEFLATE codec for the IDX dataset files
//! * [`logging`]— leveled stderr logger behind the `log_*!` macros

pub mod benchx;
pub mod check;
pub mod cli;
pub mod gzip;
pub mod json;
pub mod json_stream;
pub mod logging;
pub mod rng;
pub mod stats;
pub mod threads;
