//! Descriptive statistics used across the experiment harnesses.
//!
//! Includes the paper's *effective resolution* metric (§4): an analog
//! operation whose output spans a range R with additive noise of std σ
//! resolves `log2(R / σ)` bits — e.g. σ = 0.019 on the [-1, 1] multiply
//! output is "6.72 bits", σ = 0.098 is "4.35 bits", σ = 0.202 is "3.31 bits".

/// Running summary of a sample (Welford's algorithm: single pass, stable).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn from_slice(xs: &[f64]) -> Self {
        let mut s = Self::new();
        for &x in xs {
            s.add(x);
        }
        s
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population standard deviation.
    pub fn std(&self) -> f64 {
        if self.n == 0 {
            return f64::NAN;
        }
        (self.m2 / self.n as f64).sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Effective resolution in bits of a noisy analog value spanning `range`
/// with error std `sigma` (paper §4).
pub fn effective_bits(range: f64, sigma: f64) -> f64 {
    (range / sigma).log2()
}

/// Inverse of [`effective_bits`]: the noise std corresponding to a given
/// effective resolution over `range` — used for the Fig. 5(c) sweep.
pub fn sigma_for_bits(range: f64, bits: f64) -> f64 {
    range / 2f64.powf(bits)
}

/// Percentile by linear interpolation on a sorted copy. `q` in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let pos = q / 100.0 * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

/// Pearson correlation of two equal-length samples.
pub fn correlation(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for (&x, &y) in xs.iter().zip(ys) {
        sxy += (x - mx) * (y - my);
        sxx += (x - mx) * (x - mx);
        syy += (y - my) * (y - my);
    }
    sxy / (sxx.sqrt() * syy.sqrt())
}

/// Least-squares line fit `y = a + b x`; returns (a, b).
pub fn linfit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    for (&x, &y) in xs.iter().zip(ys) {
        sxy += (x - mx) * (y - my);
        sxx += (x - mx) * (x - mx);
    }
    let b = sxy / sxx;
    (my - b * mx, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_matches_naive() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let s = Summary::from_slice(&xs);
        assert_eq!(s.count(), 5);
        assert!((s.mean() - 4.0).abs() < 1e-12);
        let var = xs.iter().map(|x| (x - 4.0_f64).powi(2)).sum::<f64>() / 5.0;
        assert!((s.std() - var.sqrt()).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 10.0);
    }

    #[test]
    fn paper_effective_resolutions() {
        // §4: the three measured noise levels and their quoted bit-widths.
        assert!((effective_bits(2.0, 0.019) - 6.72).abs() < 0.02);
        assert!((effective_bits(2.0, 0.098) - 4.35).abs() < 0.02);
        assert!((effective_bits(2.0, 0.202) - 3.31).abs() < 0.02);
    }

    #[test]
    fn bits_sigma_roundtrip() {
        for bits in [1.0, 3.31, 4.35, 6.72, 8.0] {
            let sigma = sigma_for_bits(2.0, bits);
            assert!((effective_bits(2.0, sigma) - bits).abs() < 1e-12);
        }
    }

    #[test]
    fn percentiles() {
        let xs: Vec<f64> = (0..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 0.0);
        assert_eq!(percentile(&xs, 50.0), 50.0);
        assert_eq!(percentile(&xs, 100.0), 100.0);
        assert!((percentile(&[1.0, 2.0], 50.0) - 1.5).abs() < 1e-12);
    }

    #[test]
    fn correlation_extremes() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys: Vec<f64> = xs.iter().map(|x| 2.0 * x + 1.0).collect();
        assert!((correlation(&xs, &ys) - 1.0).abs() < 1e-12);
        let neg: Vec<f64> = xs.iter().map(|x| -x).collect();
        assert!((correlation(&xs, &neg) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn linfit_recovers_line() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ys = [1.0, 3.0, 5.0, 7.0];
        let (a, b) = linfit(&xs, &ys);
        assert!((a - 1.0).abs() < 1e-12);
        assert!((b - 2.0).abs() < 1e-12);
    }
}
