//! Micro-benchmark harness (criterion stand-in).
//!
//! Every `benches/*.rs` target (`harness = false`) uses this: warmup,
//! N timed iterations, robust summary (mean / p50 / p95 / min), optional
//! throughput units, and machine-readable one-line output so
//! `cargo bench | tee bench_output.txt` captures the paper-table rows.
//!
//! For the tracked bench *trajectory* (`BENCH_GEMM.json`,
//! `BENCH_STEP.json`, committed by CI on main pushes next to
//! `BENCH_SERVE.json`), [`BenchRecords`] accumulates results as JSON
//! rows — summary stats plus bench-specific dimensions such as thread
//! count and GEMM shape — and [`json_out_arg`] picks up the
//! `--json <path>` flag cargo forwards to `harness = false` targets.

use std::time::{Duration, Instant};

use super::json::Value;
use super::stats::percentile;

/// Configuration for a benchmark run.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    pub warmup_iters: u32,
    pub min_iters: u32,
    /// Stop adding iterations after this much measured time.
    pub max_time: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup_iters: 3,
            min_iters: 10,
            max_time: Duration::from_secs(3),
        }
    }
}

/// Result of a benchmark: per-iteration wall times.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub samples_ns: Vec<f64>,
    /// Work units per iteration (e.g. MACs) for throughput reporting.
    pub units_per_iter: Option<(f64, &'static str)>,
}

impl BenchResult {
    /// Every summary statistic of an empty sample set is pinned to 0
    /// (not the NaN mean / panicking percentile / +∞ min the naive math
    /// yields): a zero-sample result renders as an explicit "no data"
    /// row instead of poisoning report aggregation downstream.
    pub fn mean_ns(&self) -> f64 {
        if self.samples_ns.is_empty() {
            return 0.0;
        }
        self.samples_ns.iter().sum::<f64>() / self.samples_ns.len() as f64
    }

    pub fn p50_ns(&self) -> f64 {
        if self.samples_ns.is_empty() {
            return 0.0;
        }
        percentile(&self.samples_ns, 50.0)
    }

    pub fn p95_ns(&self) -> f64 {
        if self.samples_ns.is_empty() {
            return 0.0;
        }
        percentile(&self.samples_ns, 95.0)
    }

    pub fn min_ns(&self) -> f64 {
        // fold over the empty set would report +∞
        if self.samples_ns.is_empty() {
            return 0.0;
        }
        self.samples_ns.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    /// One-line human+machine readable report.
    pub fn report(&self) -> String {
        let mut line = format!(
            "bench {:<40} iters={:<4} mean={:>12} p50={:>12} p95={:>12} min={:>12}",
            self.name,
            self.samples_ns.len(),
            fmt_ns(self.mean_ns()),
            fmt_ns(self.p50_ns()),
            fmt_ns(self.p95_ns()),
            fmt_ns(self.min_ns()),
        );
        if let Some((units, label)) = self.units_per_iter {
            // an empty result has no meaningful rate; 0/s beats NaN/s
            let per_sec = if self.mean_ns() > 0.0 {
                units / (self.mean_ns() * 1e-9)
            } else {
                0.0
            };
            line.push_str(&format!(" throughput={} {label}/s", fmt_si(per_sec)));
        }
        line
    }
}

/// Time `f` under `cfg`. The closure's return value is black-boxed.
pub fn bench<T, F: FnMut() -> T>(name: &str, cfg: &BenchConfig, mut f: F) -> BenchResult {
    for _ in 0..cfg.warmup_iters {
        black_box(f());
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while samples.len() < cfg.min_iters as usize
        || (start.elapsed() < cfg.max_time && samples.len() < 10_000)
    {
        let t0 = Instant::now();
        black_box(f());
        samples.push(t0.elapsed().as_nanos() as f64);
        if start.elapsed() > cfg.max_time && samples.len() >= cfg.min_iters as usize {
            break;
        }
    }
    BenchResult { name: name.to_string(), samples_ns: samples, units_per_iter: None }
}

/// Like [`bench`] but reports throughput as `units`/second.
pub fn bench_throughput<T, F: FnMut() -> T>(
    name: &str,
    cfg: &BenchConfig,
    units: f64,
    label: &'static str,
    f: F,
) -> BenchResult {
    let mut r = bench(name, cfg, f);
    r.units_per_iter = Some((units, label));
    r
}

/// Machine-readable bench trajectory record.
///
/// Accumulates [`BenchResult`] rows (plus caller-supplied dimensions like
/// `threads` / `m` / `k` / `n`) and serializes them as one deterministic
/// JSON document:
///
/// ```json
/// {
///   "bench": "gemm_kernels",
///   "rows": [ { "name": "...", "iters": 12, "mean_ns": ..., ... } ]
/// }
/// ```
///
/// CI runs the bench binaries with `--json BENCH_GEMM.json` /
/// `--json BENCH_STEP.json` and commits the files on main pushes, so the
/// repo history carries the perf trajectory of the hot loops.
#[derive(Debug, Clone)]
pub struct BenchRecords {
    bench: String,
    rows: Vec<Value>,
}

impl BenchRecords {
    pub fn new(bench: impl Into<String>) -> BenchRecords {
        BenchRecords { bench: bench.into(), rows: Vec::new() }
    }

    /// Append one result row. `extra` carries bench-specific dimensions
    /// (thread count, GEMM shape, physics preset …) merged into the row
    /// next to the summary statistics.
    pub fn push(&mut self, r: &BenchResult, extra: Vec<(&str, Value)>) {
        let mut pairs = vec![
            ("name", Value::str(r.name.clone())),
            ("iters", Value::Number(r.samples_ns.len() as f64)),
            ("mean_ns", Value::Number(r.mean_ns())),
            ("p50_ns", Value::Number(r.p50_ns())),
            ("p95_ns", Value::Number(r.p95_ns())),
            ("min_ns", Value::Number(r.min_ns())),
        ];
        if let Some((units, label)) = r.units_per_iter {
            let per_sec = if r.mean_ns() > 0.0 {
                units / (r.mean_ns() * 1e-9)
            } else {
                0.0
            };
            pairs.push(("throughput_per_s", Value::Number(per_sec)));
            pairs.push(("throughput_unit", Value::str(label)));
        }
        pairs.extend(extra);
        self.rows.push(Value::object(pairs));
    }

    pub fn len(&self) -> usize {
        self.rows.len()
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn to_value(&self) -> Value {
        Value::object(vec![
            ("bench", Value::str(self.bench.clone())),
            ("rows", Value::Array(self.rows.clone())),
        ])
    }

    /// Serialize to `path` as pretty-printed JSON (plus trailing newline,
    /// so the committed file is POSIX-clean).
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        let mut text = self.to_value().to_string_pretty();
        text.push('\n');
        std::fs::write(path, text)
    }
}

/// The output path from a `--json <path>` pair in this process's argv.
///
/// `cargo bench --bench <target> -- --json BENCH_X.json` forwards
/// everything after `--` to the bench binary; any other flags cargo adds
/// for `harness = false` targets (notably `--bench` itself) are ignored.
pub fn json_out_arg() -> Option<String> {
    json_out_from(std::env::args().skip(1))
}

fn json_out_from<I: Iterator<Item = String>>(mut args: I) -> Option<String> {
    while let Some(a) = args.next() {
        if a == "--json" {
            return args.next();
        }
    }
    None
}

/// Opaque value sink preventing the optimizer from deleting the benchmark.
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

pub fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

pub fn fmt_si(x: f64) -> String {
    if !x.is_finite() {
        // a non-finite rate (0/0 before the first wall-clock tick,
        // overflow) renders as an explicit zero, never NaN/inf, so
        // machine-parsed report lines stay numeric
        return "0.00".to_string();
    }
    // branch on the magnitude so negative values pick up the same SI
    // suffix as their absolute value (-2e6 -> "-2.00M", not "-2000000.00")
    let (div, suffix) = match x.abs() {
        a if a >= 1e12 => (1e12, "T"),
        a if a >= 1e9 => (1e9, "G"),
        a if a >= 1e6 => (1e6, "M"),
        a if a >= 1e3 => (1e3, "k"),
        _ => (1.0, ""),
    };
    format!("{:.2}{suffix}", x / div)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_at_least_min_iters() {
        let cfg = BenchConfig {
            warmup_iters: 1,
            min_iters: 5,
            max_time: Duration::from_millis(50),
        };
        let r = bench("noop", &cfg, || 1 + 1);
        assert!(r.samples_ns.len() >= 5);
        assert!(r.mean_ns() >= 0.0);
        assert!(r.min_ns() <= r.p50_ns());
        assert!(r.p50_ns() <= r.p95_ns() + 1.0);
    }

    #[test]
    fn report_contains_throughput() {
        let cfg = BenchConfig {
            warmup_iters: 0,
            min_iters: 3,
            max_time: Duration::from_millis(10),
        };
        let r = bench_throughput("tp", &cfg, 1000.0, "MAC", || {
            std::hint::black_box((0..100).sum::<u64>())
        });
        let line = r.report();
        assert!(line.contains("MAC/s"), "{line}");
        assert!(line.contains("bench tp"));
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_ns(500.0), "500 ns");
        assert!(fmt_ns(1500.0).contains("µs"));
        assert!(fmt_ns(2.5e6).contains("ms"));
        assert!(fmt_ns(3.2e9).contains(" s"));
        assert_eq!(fmt_si(2.0e13), "20.00T");
        assert_eq!(fmt_si(2.5e9), "2.50G");
        assert_eq!(fmt_si(3.0e6), "3.00M");
        assert_eq!(fmt_si(1.5e3), "1.50k");
        assert_eq!(fmt_si(5.0), "5.00");
        assert_eq!(fmt_si(1e3), "1.00k"); // boundary lands on the suffix
    }

    #[test]
    fn fmt_si_negative_and_nonfinite() {
        // regression: negatives fell through every `x >= threshold`
        // branch ("-2000000.00"), and NaN rendered literally in report
        // lines parsed by the bench tooling
        assert_eq!(fmt_si(-2.0e6), "-2.00M");
        assert_eq!(fmt_si(-2.5e9), "-2.50G");
        assert_eq!(fmt_si(-1.5e3), "-1.50k");
        assert_eq!(fmt_si(-5.0), "-5.00");
        assert_eq!(fmt_si(f64::NAN), "0.00");
        assert_eq!(fmt_si(f64::INFINITY), "0.00");
        assert_eq!(fmt_si(f64::NEG_INFINITY), "0.00");
        assert_eq!(fmt_si(0.0), "0.00");
    }

    fn result_with(samples: &[f64]) -> BenchResult {
        BenchResult {
            name: "synthetic".into(),
            samples_ns: samples.to_vec(),
            units_per_iter: None,
        }
    }

    #[test]
    fn summary_math_on_known_samples() {
        // 1..=100 ns: mean 50.5, p50 = 50/51 midpoint-ish, min 1
        let samples: Vec<f64> = (1..=100).map(|v| v as f64).collect();
        let r = result_with(&samples);
        assert!((r.mean_ns() - 50.5).abs() < 1e-9);
        assert!((r.p50_ns() - 50.5).abs() <= 1.0, "{}", r.p50_ns());
        assert!((r.p95_ns() - 95.0).abs() <= 1.0, "{}", r.p95_ns());
        assert_eq!(r.min_ns(), 1.0);
        // order independence of the percentile summary
        let mut rev = samples.clone();
        rev.reverse();
        let rr = result_with(&rev);
        assert_eq!(r.p50_ns(), rr.p50_ns());
        assert_eq!(r.p95_ns(), rr.p95_ns());
    }

    #[test]
    fn summary_math_degenerate_cases() {
        let one = result_with(&[42.0]);
        assert_eq!(one.mean_ns(), 42.0);
        assert_eq!(one.p50_ns(), 42.0);
        assert_eq!(one.p95_ns(), 42.0);
        assert_eq!(one.min_ns(), 42.0);
        let flat = result_with(&[7.0; 16]);
        assert_eq!(flat.mean_ns(), 7.0);
        assert_eq!(flat.p50_ns(), 7.0);
    }

    #[test]
    fn empty_samples_yield_zeros_not_garbage() {
        // regression: mean was NaN (0/0), min +inf, and the percentile
        // call panicked on an empty sample set
        let empty = result_with(&[]);
        assert_eq!(empty.mean_ns(), 0.0);
        assert_eq!(empty.p50_ns(), 0.0);
        assert_eq!(empty.p95_ns(), 0.0);
        assert_eq!(empty.min_ns(), 0.0);
        let line = empty.report();
        assert!(line.contains("iters=0"), "{line}");
        assert!(!line.contains("NaN") && !line.contains("inf"), "{line}");
        // throughput over zero samples reports a zero rate, not NaN/s
        let mut tp = result_with(&[]);
        tp.units_per_iter = Some((1000.0, "MAC"));
        let line = tp.report();
        assert!(line.contains("throughput=0.00 MAC/s"), "{line}");
    }

    #[test]
    fn throughput_uses_mean() {
        // 1000 units at a steady 1 µs/iter -> 1e9 units/s -> "1.00G"
        let mut r = result_with(&[1000.0; 8]);
        r.units_per_iter = Some((1000.0, "MAC"));
        let line = r.report();
        assert!(line.contains("throughput=1.00G MAC/s"), "{line}");
    }

    #[test]
    fn records_round_trip_through_json() {
        let mut rec = BenchRecords::new("unit_test");
        assert!(rec.is_empty());
        let mut r = result_with(&[1000.0; 8]);
        r.units_per_iter = Some((1000.0, "MAC"));
        rec.push(
            &r,
            vec![
                ("threads", Value::Number(4.0)),
                ("m", Value::Number(64.0)),
                ("kernel", Value::str("matmul")),
            ],
        );
        rec.push(&result_with(&[5.0, 7.0]), vec![]);
        assert_eq!(rec.len(), 2);

        let parsed = Value::parse(&rec.to_value().to_string_pretty()).unwrap();
        assert_eq!(parsed.get("bench").as_str(), Some("unit_test"));
        let rows = parsed.get("rows").as_array().unwrap();
        assert_eq!(rows.len(), 2);
        let row = &rows[0];
        assert_eq!(row.get("name").as_str(), Some("synthetic"));
        assert_eq!(row.get("iters").as_usize(), Some(8));
        assert_eq!(row.get("mean_ns").as_f64(), Some(1000.0));
        assert_eq!(row.get("min_ns").as_f64(), Some(1000.0));
        assert_eq!(row.get("threads").as_usize(), Some(4));
        assert_eq!(row.get("kernel").as_str(), Some("matmul"));
        // 1000 units / 1 µs = 1e9 per second
        assert_eq!(row.get("throughput_per_s").as_f64(), Some(1e9));
        assert_eq!(row.get("throughput_unit").as_str(), Some("MAC"));
        // the throughput fields are optional per row
        assert_eq!(rows[1].get("throughput_per_s"), &Value::Null);
    }

    #[test]
    fn records_write_emits_parseable_file() {
        let mut rec = BenchRecords::new("file_test");
        rec.push(&result_with(&[10.0, 20.0, 30.0]), vec![]);
        let path = std::env::temp_dir().join("benchx_records_unit_test.json");
        let path = path.to_str().unwrap().to_string();
        rec.write(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert!(text.ends_with('\n'), "committed record must end in newline");
        let parsed = Value::parse(text.trim_end()).unwrap();
        assert_eq!(parsed.get("bench").as_str(), Some("file_test"));
        assert_eq!(parsed.get("rows").as_array().unwrap().len(), 1);
    }

    #[test]
    fn json_out_flag_parsing() {
        let argv = |v: &[&str]| v.iter().map(|s| s.to_string());
        // cargo's own `--bench` flag (and anything else) is skipped
        assert_eq!(
            json_out_from(argv(&["--bench", "--json", "B.json"])),
            Some("B.json".to_string())
        );
        assert_eq!(json_out_from(argv(&["--json"])), None); // missing value
        assert_eq!(json_out_from(argv(&["--bench"])), None);
        assert_eq!(json_out_from(argv(&[])), None);
    }
}
