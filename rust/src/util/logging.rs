//! Leveled stderr logger, self-contained (the offline vendor set lacks the
//! `log` facade, so the crate carries its own).
//!
//! `PDFA_LOG=debug pdfa train ...` controls verbosity; default is `info`.
//! Call sites use the [`crate::log_info!`], [`crate::log_warn!`] and
//! [`crate::log_debug!`] macros, which route through [`log`] and print
//! nothing when the record's level is filtered out.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Log severity, most severe first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    fn label(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);
static START: OnceLock<Instant> = OnceLock::new();

/// Install the logger; level from `PDFA_LOG` (error|warn|info|debug|trace).
/// Safe to call repeatedly; the relative-time clock starts at first call.
pub fn init() {
    let level = match std::env::var("PDFA_LOG").as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("debug") => Level::Debug,
        Ok("trace") => Level::Trace,
        _ => Level::Info,
    };
    set_level(level);
}

/// Set the maximum emitted level directly (tests, embedding).
pub fn set_level(level: Level) {
    MAX_LEVEL.store(level as u8, Ordering::Relaxed);
    // lint: timing: log-line timestamps only, never feeds computation
    let _ = START.get_or_init(Instant::now);
}

/// Whether a record at `level` would currently be emitted.
pub fn enabled(level: Level) -> bool {
    level as u8 <= MAX_LEVEL.load(Ordering::Relaxed)
}

/// Back end of the `log_*!` macros; prefer those at call sites.
pub fn log(level: Level, target: &str, args: std::fmt::Arguments<'_>) {
    if !enabled(level) {
        return;
    }
    // lint: timing: log-line timestamps only, never feeds computation
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    eprintln!(
        "[{t:9.3}s {:5} {}] {args}",
        level.label(),
        target.rsplit("::").next().unwrap_or(""),
    );
}

/// Log at info level to stderr, timestamped; filtered by `PDFA_LOG`.
#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Info,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

/// Log at warn level to stderr, timestamped; filtered by `PDFA_LOG`.
#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Warn,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

/// Log at debug level to stderr, timestamped; filtered by `PDFA_LOG`.
#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        $crate::util::logging::log(
            $crate::util::logging::Level::Debug,
            module_path!(),
            format_args!($($arg)*),
        )
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    // One combined test: MAX_LEVEL is process-global state, and two
    // #[test]s mutating it race under the parallel test runner.
    #[test]
    fn init_macros_and_level_filtering() {
        init();
        init();
        crate::log_info!("logging smoke test {}", 42);
        crate::log_warn!("warn smoke test");
        crate::log_debug!("filtered at default level");

        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        assert!(!enabled(Level::Trace));
        set_level(Level::Trace);
        assert!(enabled(Level::Debug));
        // restore the default so other tests' stderr stays quiet
        set_level(Level::Info);
    }
}
