//! GeMM-compiler bench: planning cost and tiled mat-vec execution over the
//! numeric and device executors for the paper's layer shapes.
//!
//! Supports the same `--json <path>` machine-readable record flag as the
//! `gemm_kernels` / `photonic_step` trajectory benches.

use photonic_dfa::dfa::device_backend::DeviceBackend;
use photonic_dfa::gemm::compiler::{GemmCompiler, NumericExecutor};
use photonic_dfa::gemm::schedule::Order;
use photonic_dfa::photonics::BpdMode;
use photonic_dfa::tensor::Tensor;
use photonic_dfa::util::benchx::{
    bench, bench_throughput, json_out_arg, BenchConfig, BenchRecords,
};
use photonic_dfa::util::json::Value;
use photonic_dfa::util::rng::Pcg64;

fn main() {
    let cfg = BenchConfig::default();
    let mut records = BenchRecords::new("gemm_compiler");
    let mut rng = Pcg64::seed(1);

    // planning cost for the paper's 800x10 feedback matrix
    let exec = NumericExecutor::new(50, 20);
    let r = bench("gemm/plan_800x10_on_50x20", &cfg, || {
        GemmCompiler::plan(800, 10, &exec, Order::ColMajor).unwrap()
    });
    println!("{}", r.report());
    records.push(&r, vec![("stage", Value::str("plan"))]);

    // numeric execution (16 cycles per matvec)
    let bmat = Tensor::rand_uniform(&[800, 10], -1.0, 1.0, &mut rng);
    let e: Vec<f32> = (0..10).map(|_| rng.normal(0.0, 0.5) as f32).collect();
    let mut exec = NumericExecutor::new(50, 20);
    let plan = GemmCompiler::plan(800, 10, &exec, Order::ColMajor).unwrap();
    let r = bench_throughput(
        "gemm/numeric_matvec_800x10",
        &cfg,
        (800 * 10) as f64,
        "MAC",
        || plan.matvec(&mut exec, &bmat, &e).unwrap(),
    );
    println!("{}", r.report());
    records.push(&r, vec![("stage", Value::str("numeric_matvec"))]);

    // device-level execution with pre-compiled (analog-memory) tiles
    let mut be = DeviceBackend::new(BpdMode::OffChip, 3).unwrap();
    let fb = be.compile_feedback(&bmat).unwrap();
    let r = bench_throughput(
        "gemm/device_matvec_800x10",
        &cfg,
        (800 * 10) as f64,
        "MAC",
        || be.matvec(&fb, &e, None).unwrap(),
    );
    println!("{}", r.report());
    records.push(&r, vec![("stage", Value::str("device_matvec"))]);

    // schedule statistics for the paper's case (prints the cycle count the
    // energy model consumes)
    let stats = plan.schedule.stats(10e9, true);
    println!(
        "gemm/schedule_800x10: cycles={} encodes={} macs={} compute_time={:.2} ns @10GHz",
        stats.cycles,
        stats.input_encodes,
        stats.macs,
        stats.compute_time_s * 1e9
    );

    if let Some(path) = json_out_arg() {
        records.write(&path).expect("write bench record");
        println!("gemm_compiler: wrote {} rows to {path}", records.len());
    }
}
