//! Bench for Fig. 5(a): 1×4 photonic inner products through both BPD
//! circuits — error statistics + device-sim measurement rate.

use photonic_dfa::experiments::fig5a_inner_products;
use photonic_dfa::photonics::{BankConfig, BpdMode, WeightBank};
use photonic_dfa::util::benchx::{bench_throughput, BenchConfig};
use photonic_dfa::util::rng::Pcg64;

fn main() {
    let cfg = BenchConfig::default();

    for (label, mode, paper_sigma) in [
        ("offchip", BpdMode::OffChip, 0.098),
        ("onchip", BpdMode::OnChip, 0.202),
    ] {
        let m = fig5a_inner_products(mode, 2000, 7).unwrap();
        println!(
            "fig5a/{label}: sigma={:.4} mean={:+.4} bits={:.2} [paper sigma {paper_sigma}]",
            m.sigma, m.mean, m.effective_bits
        );
    }

    // full measurement loop (inscribe + read), the experiment's inner loop
    let mut bank = WeightBank::new(BankConfig::testbed(BpdMode::OffChip)).unwrap();
    let mut rng = Pcg64::seed(3);
    let r = bench_throughput(
        "fig5a/measurement_incl_inscribe",
        &cfg,
        4.0,
        "MAC",
        || {
            let w: Vec<f32> = (0..4).map(|_| rng.uniform_in(-1.0, 1.0) as f32).collect();
            let x: Vec<f32> = (0..4).map(|_| rng.uniform() as f32).collect();
            bank.inner_product(&x, &w).unwrap()
        },
    );
    println!("{}", r.report());

    // pure optical cycles on a locked bank (the hardware's 10 GHz path)
    let tile = photonic_dfa::tensor::Tensor::new(&[1, 4], vec![0.5, -0.2, 0.8, 0.1])
        .unwrap();
    bank.inscribe(&tile).unwrap();
    let r = bench_throughput("fig5a/locked_bank_cycle", &cfg, 4.0, "MAC", || {
        bank.matvec(&[0.9, 0.4, 0.6, 0.2]).unwrap()
    });
    println!("{}", r.report());
}
