//! Bench for Fig. 5(b): end-to-end training-step latency of the fused AOT
//! artifacts (the workload behind the validation curves).
//!
//! Reports per-step latency and MAC throughput for the DFA step (with and
//! without noise) and the backprop baseline, per network config.

use photonic_dfa::dfa::params::NetState;
use photonic_dfa::runtime::{self, Backend};
use photonic_dfa::tensor::Tensor;
use photonic_dfa::util::benchx::{bench_throughput, BenchConfig};
use photonic_dfa::util::rng::Pcg64;

fn main() {
    let engine = runtime::open("artifacts", Backend::Auto).expect("open step engine");
    let bench_cfg = BenchConfig::default();
    println!("backend: {}", engine.platform_name());

    for config in ["tiny", "small", "mnist"] {
        let dims = engine.net_dims(config).unwrap();
        let mut rng = Pcg64::seed(1);
        let state = NetState::init(&dims, &mut rng);
        let (b1, b2) = NetState::init_feedback(&dims, &mut rng);
        let x = Tensor::rand_uniform(&[dims.batch, dims.d_in], 0.0, 1.0, &mut rng);
        let mut y = Tensor::zeros(&[dims.batch, dims.d_out]);
        for r in 0..dims.batch {
            y.set(r, (r % dims.d_out) as usize, 1.0);
        }
        let n1 = Tensor::randn(&[dims.d_h1, dims.batch], 1.0, &mut rng);
        let n2 = Tensor::randn(&[dims.d_h2, dims.batch], 1.0, &mut rng);

        // total forward+backward+update MACs per step (dense layers x2 for
        // fwd+update outer products + the DFA gradient matvec)
        let fwd_macs = dims.batch
            * (dims.d_in * dims.d_h1 + dims.d_h1 * dims.d_h2 + dims.d_h2 * dims.d_out);
        let dfa_macs = dims.batch * (dims.d_h1 + dims.d_h2) * dims.d_out;
        let macs = (3 * fwd_macs + dfa_macs) as f64;

        let dfa = engine.load(&format!("dfa_step_{config}")).unwrap();
        let mut inputs: Vec<Tensor> = state.tensors.clone();
        inputs.extend([
            b1.clone(), b2.clone(), x.clone(), y.clone(), n1.clone(), n2.clone(),
            Tensor::scalar(0.098), Tensor::scalar(0.0),
            Tensor::scalar(0.01), Tensor::scalar(0.9),
        ]);
        let r = bench_throughput(
            &format!("fig5b/dfa_step_{config}"),
            &bench_cfg,
            macs,
            "MAC",
            || dfa.execute(&inputs).unwrap(),
        );
        println!("{}", r.report());

        let bp = engine.load(&format!("bp_step_{config}")).unwrap();
        let mut bp_inputs: Vec<Tensor> = state.tensors.clone();
        bp_inputs.extend([
            x.clone(), y.clone(), Tensor::scalar(0.01), Tensor::scalar(0.9),
        ]);
        let r = bench_throughput(
            &format!("fig5b/bp_step_{config}"),
            &bench_cfg,
            macs,
            "MAC",
            || bp.execute(&bp_inputs).unwrap(),
        );
        println!("{}", r.report());
    }
}
