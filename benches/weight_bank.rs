//! Device-simulator bench: the 50×20 photonic weight bank's operational
//! cycle, inscription, calibration and analog-memory switch costs — the
//! hot path of device-mode training.

use photonic_dfa::photonics::{BankConfig, BpdMode, WeightBank};
use photonic_dfa::tensor::Tensor;
use photonic_dfa::util::benchx::{bench, bench_throughput, BenchConfig};
use photonic_dfa::util::rng::Pcg64;

fn main() {
    let cfg = BenchConfig::default();
    let mut rng = Pcg64::seed(1);

    // bank construction includes per-ring fabrication + calibration
    // lint: timing: wall-clock is the measurement itself
    let t0 = std::time::Instant::now();
    let mut bank = WeightBank::new(BankConfig::paper(BpdMode::OffChip)).unwrap();
    println!(
        "weight_bank/build_and_calibrate_50x20 once: {:.2?} (1000 rings)",
        t0.elapsed()
    );

    let tile = Tensor::rand_uniform(&[50, 20], -0.9, 0.9, &mut rng);
    let r = bench("weight_bank/inscribe_50x20", &cfg, || {
        bank.inscribe(&tile).unwrap()
    });
    println!("{}", r.report());

    let snap = bank.snapshot();
    let r = bench("weight_bank/analog_memory_restore", &cfg, || {
        bank.restore(&snap).unwrap()
    });
    println!("{}", r.report());

    let x: Vec<f32> = (0..20).map(|_| rng.uniform() as f32).collect();
    let r = bench_throughput(
        "weight_bank/cycle_50x20",
        &cfg,
        (50 * 20) as f64,
        "MAC",
        || bank.matvec(&x).unwrap(),
    );
    println!("{}", r.report());

    // ideal (noise-free) bank: the numeric floor of the simulator
    let mut ideal = WeightBank::new(BankConfig::paper(BpdMode::Ideal)).unwrap();
    ideal.inscribe(&tile).unwrap();
    let r = bench_throughput(
        "weight_bank/cycle_50x20_ideal",
        &cfg,
        (50 * 20) as f64,
        "MAC",
        || ideal.matvec(&x).unwrap(),
    );
    println!("{}", r.report());
}
