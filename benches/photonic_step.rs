//! Photonic-backend bench: cost of one in-situ training dispatch.
//!
//! Times the `fwd` and `dfa_step` artifacts of the tiny config on the
//! [`PhotonicEngine`] under (a) the ideal preset (exact inscription — the
//! per-cycle optical chain dominates) and (b) the paper preset with
//! feedback-locked inscription (the §4 lock protocol dominates), plus the
//! one-off bank build (fabrication + calibration) cost. MAC throughput is
//! reported against the gradient-path MACs the dispatch performs.
//!
//! Writes the machine-readable record CI commits on main pushes:
//!
//! ```text
//! cargo bench --bench photonic_step -- --json BENCH_STEP.json
//! ```

use photonic_dfa::dfa::params::NetState;
use photonic_dfa::runtime::{PhotonicEngine, PhysicsConfig, StepEngine};
use photonic_dfa::tensor::Tensor;
use photonic_dfa::util::benchx::{
    bench, bench_throughput, json_out_arg, BenchConfig, BenchRecords,
};
use photonic_dfa::util::json::Value;
use photonic_dfa::util::rng::Pcg64;

fn main() {
    let mut records = BenchRecords::new("photonic_step");
    let cfg = BenchConfig {
        warmup_iters: 1,
        min_iters: 5,
        max_time: std::time::Duration::from_secs(2),
    };

    for (label, physics) in [
        ("ideal", PhysicsConfig::ideal()),
        ("paper", PhysicsConfig::paper()),
    ] {
        // lint: timing: wall-clock is the measurement itself
        let t0 = std::time::Instant::now();
        let engine = PhotonicEngine::open("artifacts", physics).unwrap();
        let fwd = engine.load("fwd_tiny").unwrap();
        let step = engine.load("dfa_step_tiny").unwrap();
        println!(
            "photonic/bank_build_{label} (fabricate + calibrate, once per \
             artifact): {:.2?}",
            t0.elapsed()
        );

        let dims = engine.net_dims("tiny").unwrap();
        let mut rng = Pcg64::seed(1);
        let state = NetState::init(&dims, &mut rng);
        let (b1, b2) = NetState::init_feedback(&dims, &mut rng);
        let x = Tensor::rand_uniform(&[dims.batch, dims.d_in], 0.0, 1.0, &mut rng);
        let mut y = Tensor::zeros(&[dims.batch, dims.d_out]);
        for r in 0..dims.batch {
            y.set(r, r % dims.d_out, 1.0);
        }

        let mut fwd_inputs: Vec<Tensor> = state.tensors[..6].to_vec();
        fwd_inputs.push(x.clone());
        let r = bench(&format!("photonic/fwd_tiny_{label}"), &cfg, || {
            fwd.execute(&fwd_inputs).unwrap()
        });
        println!("{}", r.report());
        records.push(
            &r,
            vec![
                ("net", Value::str("tiny")),
                ("physics", Value::str(label)),
                ("artifact", Value::str("fwd")),
                ("threads", Value::Number(1.0)),
            ],
        );

        let mut step_inputs = state.tensors.clone();
        step_inputs.extend([
            b1.clone(),
            b2.clone(),
            x.clone(),
            y.clone(),
            Tensor::zeros(&[dims.d_h1, dims.batch]),
            Tensor::zeros(&[dims.d_h2, dims.batch]),
            Tensor::scalar(0.0),
            Tensor::scalar(0.0),
            Tensor::scalar(0.05),
            Tensor::scalar(0.9),
        ]);
        let gradient_macs = ((dims.d_h1 + dims.d_h2) * dims.d_out * dims.batch) as f64;
        let r = bench_throughput(
            &format!("photonic/dfa_step_tiny_{label}"),
            &cfg,
            gradient_macs,
            "MAC",
            || step.execute(&step_inputs).unwrap(),
        );
        println!("{}", r.report());
        records.push(
            &r,
            vec![
                ("net", Value::str("tiny")),
                ("physics", Value::str(label)),
                ("artifact", Value::str("dfa_step")),
                ("threads", Value::Number(1.0)),
            ],
        );

        // the telemetry roll-up of everything the bench dispatched: the
        // §5-modeled energy figure next to the wall-clock numbers above
        let t = engine.telemetry();
        println!(
            "photonic/telemetry_{label}: {} MACs ({} on-bank), {} cycles, \
             {} modeled{}",
            t.macs,
            t.photonic_macs,
            t.cycles,
            photonic_dfa::telemetry::report::fmt_joules(t.energy_j),
            t.pj_per_mac()
                .map_or(String::new(), |pj| format!(", {pj:.2} pJ/MAC")),
        );
    }

    // ---- batch-row sharding: thread scaling 1/2/4/all, mnist-sized ----
    // Ideal physics so the per-cycle optical chain (the part the worker
    // pool shards) dominates rather than the lock protocol. Outputs are
    // bit-identical across every row; only the wall clock moves.
    let threads_cfg = BenchConfig {
        warmup_iters: 0,
        min_iters: 2,
        max_time: std::time::Duration::from_secs(4),
    };
    let all_cores = photonic_dfa::util::threads::available();
    let mut thread_counts = vec![1usize, 2, 4];
    thread_counts.retain(|&t| t <= all_cores);
    if !thread_counts.contains(&all_cores) {
        thread_counts.push(all_cores);
    }
    for threads in thread_counts {
        let engine =
            PhotonicEngine::open_threaded("artifacts", PhysicsConfig::ideal(), threads)
                .unwrap();
        let step = engine.load("dfa_step_mnist").unwrap();
        let dims = engine.net_dims("mnist").unwrap();
        let mut rng = Pcg64::seed(2);
        let state = NetState::init(&dims, &mut rng);
        let (b1, b2) = NetState::init_feedback(&dims, &mut rng);
        let x = Tensor::rand_uniform(&[dims.batch, dims.d_in], 0.0, 1.0, &mut rng);
        let mut y = Tensor::zeros(&[dims.batch, dims.d_out]);
        for r in 0..dims.batch {
            y.set(r, r % dims.d_out, 1.0);
        }
        let mut step_inputs = state.tensors.clone();
        step_inputs.extend([
            b1,
            b2,
            x,
            y,
            Tensor::zeros(&[dims.d_h1, dims.batch]),
            Tensor::zeros(&[dims.d_h2, dims.batch]),
            Tensor::scalar(0.0),
            Tensor::scalar(0.0),
            Tensor::scalar(0.05),
            Tensor::scalar(0.9),
        ]);
        let gradient_macs = ((dims.d_h1 + dims.d_h2) * dims.d_out * dims.batch) as f64;
        let r = bench_throughput(
            &format!("photonic/dfa_step_mnist_ideal_threads{threads}"),
            &threads_cfg,
            gradient_macs,
            "MAC",
            || step.execute(&step_inputs).unwrap(),
        );
        println!("{}", r.report());
        records.push(
            &r,
            vec![
                ("net", Value::str("mnist")),
                ("physics", Value::str("ideal")),
                ("artifact", Value::str("dfa_step")),
                ("threads", Value::Number(threads as f64)),
            ],
        );
    }

    if let Some(path) = json_out_arg() {
        records.write(&path).expect("write bench record");
        println!("photonic_step: wrote {} rows to {path}", records.len());
    }
}
