//! Bench for Fig. 3(c): single-MRR multiplication — device-sim throughput
//! and the error statistics the paper reports (σ = 0.019, 6.72 bits).

use photonic_dfa::experiments::fig3c_multiply;
use photonic_dfa::photonics::{BankConfig, BpdMode, WeightBank};
use photonic_dfa::util::benchx::{bench_throughput, BenchConfig};
use photonic_dfa::util::rng::Pcg64;

fn main() {
    let cfg = BenchConfig::default();

    // correctness numbers first (what the figure actually shows)
    let m = fig3c_multiply(3900, 7).unwrap();
    println!(
        "fig3c stats: n={} sigma={:.4} mean={:+.4} bits={:.2} [paper 0.019 / 6.72]",
        m.n, m.sigma, m.mean, m.effective_bits
    );

    // throughput of the device-level multiply (inscribe + readout)
    let mut bank = WeightBank::new(BankConfig {
        rows: 1,
        cols: 1,
        ..BankConfig::testbed(BpdMode::SingleMrr)
    })
    .unwrap();
    let mut rng = Pcg64::seed(1);
    let r = bench_throughput("fig3c/multiply_with_inscribe", &cfg, 1.0, "mult", || {
        let x = rng.uniform() as f32;
        let w = rng.uniform_in(-1.0, 1.0) as f32;
        bank.multiply(x, w).unwrap()
    });
    println!("{}", r.report());

    // readout-only path (weights already locked — the per-cycle cost)
    let mut bank2 = WeightBank::new(BankConfig {
        rows: 1,
        cols: 1,
        ..BankConfig::testbed(BpdMode::SingleMrr)
    })
    .unwrap();
    let tile = photonic_dfa::tensor::Tensor::new(&[1, 1], vec![0.5]).unwrap();
    bank2.inscribe(&tile).unwrap();
    let r = bench_throughput("fig3c/readout_only_cycle", &cfg, 1.0, "cycle", || {
        bank2.matvec(&[0.7]).unwrap()
    });
    println!("{}", r.report());
}
