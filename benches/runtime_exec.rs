//! Runtime bench: per-dispatch overhead of the active step engine.
//!
//! Times the `fwd` artifact execution per config on whichever backend is
//! active. With `--features pjrt` it additionally separates Tensor ->
//! Literal marshalling and one-off artifact compile cost, to keep the
//! coordinator's overhead honest (perf target: marshalling < 10% of step
//! latency on the mnist config).

use photonic_dfa::dfa::params::NetState;
use photonic_dfa::runtime::{self, Backend};
use photonic_dfa::tensor::Tensor;
use photonic_dfa::util::benchx::{bench, BenchConfig};
use photonic_dfa::util::rng::Pcg64;

fn main() {
    let engine = runtime::open("artifacts", Backend::Auto).expect("open step engine");
    let cfg = BenchConfig::default();
    println!("backend: {}", engine.platform_name());

    for config in ["small", "mnist"] {
        let dims = engine.net_dims(config).unwrap();
        let mut rng = Pcg64::seed(1);
        let state = NetState::init(&dims, &mut rng);
        let x = Tensor::rand_uniform(&[dims.batch, dims.d_in], 0.0, 1.0, &mut rng);
        let fwd = engine.load(&format!("fwd_{config}")).unwrap();
        let mut inputs: Vec<Tensor> = state.tensors[..6].to_vec();
        inputs.push(x);

        #[cfg(feature = "pjrt")]
        {
            use photonic_dfa::runtime::engine::tensor_to_literal;
            let r = bench(&format!("runtime/marshal_inputs_{config}"), &cfg, || {
                inputs
                    .iter()
                    .map(|t| tensor_to_literal(t).unwrap())
                    .collect::<Vec<_>>()
            });
            println!("{}", r.report());
        }

        let r = bench(&format!("runtime/fwd_execute_{config}"), &cfg, || {
            fwd.execute(&inputs).unwrap()
        });
        println!("{}", r.report());

        // analytic MAC cost of one dispatch (telemetry layer), giving the
        // wall-clock MAC/s this backend sustains on the fwd path
        let macs_per_exec =
            photonic_dfa::telemetry::macs_forward(&dims) as f64;
        let mac_per_s = if r.mean_ns() > 0.0 {
            macs_per_exec / (r.mean_ns() * 1e-9)
        } else {
            0.0
        };
        println!(
            "runtime/fwd_macs_{config}: {macs_per_exec} MACs/dispatch, {} MAC/s",
            photonic_dfa::util::benchx::fmt_si(mac_per_s)
        );
    }

    // artifact load cost (for PJRT: HLO compile, amortised once per
    // process by the executable cache)
    // lint: timing: wall-clock is the measurement itself
    let t0 = std::time::Instant::now();
    let fresh = runtime::open("artifacts", Backend::Auto).unwrap();
    fresh.load("dfa_step_small").unwrap();
    println!(
        "runtime/load_dfa_step_small once: {:.2?} (cached afterwards)",
        t0.elapsed()
    );
}
