//! Runtime bench: PJRT dispatch + marshalling overhead per artifact.
//!
//! Separates (a) Tensor -> Literal conversion, (b) execute, and (c) output
//! decomposition, to keep the coordinator's overhead honest (perf target:
//! marshalling < 10% of step latency on the mnist config).

use std::sync::Arc;

use photonic_dfa::dfa::params::NetState;
use photonic_dfa::runtime::engine::tensor_to_literal;
use photonic_dfa::runtime::Engine;
use photonic_dfa::tensor::Tensor;
use photonic_dfa::util::benchx::{bench, BenchConfig};
use photonic_dfa::util::rng::Pcg64;

fn main() {
    let engine = Arc::new(Engine::new("artifacts").expect("run `make artifacts`"));
    let cfg = BenchConfig::default();

    for config in ["small", "mnist"] {
        let dims = engine.manifest().net_dims(config).unwrap().clone();
        let mut rng = Pcg64::seed(1);
        let state = NetState::init(&dims, &mut rng);
        let x = Tensor::rand_uniform(&[dims.batch, dims.d_in], 0.0, 1.0, &mut rng);
        let fwd = engine.load(&format!("fwd_{config}")).unwrap();
        let mut inputs: Vec<Tensor> = state.tensors[..6].to_vec();
        inputs.push(x);

        let r = bench(&format!("runtime/marshal_inputs_{config}"), &cfg, || {
            inputs
                .iter()
                .map(|t| tensor_to_literal(t).unwrap())
                .collect::<Vec<_>>()
        });
        println!("{}", r.report());

        let r = bench(&format!("runtime/fwd_execute_{config}"), &cfg, || {
            fwd.execute(&inputs).unwrap()
        });
        println!("{}", r.report());
    }

    // artifact compile cost (amortised once per process by the cache)
    let t0 = std::time::Instant::now();
    let fresh = Engine::new("artifacts").unwrap();
    fresh.load("dfa_step_small").unwrap();
    println!(
        "runtime/compile_dfa_step_small once: {:.2?} (cached afterwards)",
        t0.elapsed()
    );
}
