//! Serving-stack throughput: burst-submit batches of single-sample
//! requests through the dynamic batcher + worker pool and measure
//! end-to-end request throughput, vs the raw forward-artifact floor.
//!
//! Run: `cargo bench --bench serve_throughput`

use std::sync::Arc;
use std::time::Duration;

use photonic_dfa::dfa::params::NetState;
use photonic_dfa::runtime::{NativeEngine, StepEngine};
use photonic_dfa::serve::{BatchPolicy, ServeConfig, Server};
use photonic_dfa::tensor::Tensor;
use photonic_dfa::util::benchx::{bench_throughput, black_box, BenchConfig};
use photonic_dfa::util::rng::Pcg64;

const BURST: usize = 64;

fn requests(d_in: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Pcg64::seed(seed);
    (0..BURST)
        .map(|_| (0..d_in).map(|_| rng.uniform() as f32).collect())
        .collect()
}

fn main() {
    let cfg = BenchConfig { warmup_iters: 2, min_iters: 10, max_time: Duration::from_secs(2) };
    let engine: Arc<dyn StepEngine> = Arc::new(NativeEngine::new());

    for config in ["tiny", "small"] {
        let dims = engine.net_dims(config).unwrap();
        let mut rng = Pcg64::seed(7);
        let state = NetState::init(&dims, &mut rng);

        // floor: the raw fwd artifact at its traced batch size
        let fwd = engine.load(&format!("fwd_{config}")).unwrap();
        let mut inputs: Vec<Tensor> = state.params().to_vec();
        inputs.push(Tensor::randn(&[dims.batch, dims.d_in], 0.5, &mut rng));
        let r = bench_throughput(
            &format!("fwd_artifact_{config}"),
            &cfg,
            dims.batch as f64,
            "req",
            || black_box(fwd.execute(&inputs).unwrap()),
        );
        println!("{}", r.report());

        // the serving stack, a few pool/batch shapes
        for (workers, max_batch) in [(1, dims.batch), (2, dims.batch), (4, 2 * dims.batch)] {
            let server = Server::start(
                &engine,
                config,
                state.params(),
                ServeConfig {
                    workers,
                    policy: BatchPolicy {
                        max_batch,
                        max_wait: Duration::from_millis(1),
                        queue_cap: 4 * BURST,
                    },
                },
            )
            .unwrap();
            let reqs = requests(dims.d_in, 42);
            let r = bench_throughput(
                &format!("serve_{config}_w{workers}_b{max_batch}"),
                &cfg,
                BURST as f64,
                "req",
                || {
                    let tickets: Vec<_> = reqs
                        .iter()
                        .map(|x| server.submit(x.clone()).unwrap())
                        .collect();
                    for t in tickets {
                        black_box(t.wait().unwrap());
                    }
                },
            );
            println!("{}", r.report());
            println!("{}", server.shutdown().report());
        }
    }
}
