//! Serving-stack throughput: burst-submit batches of single-sample
//! requests through the dynamic batcher + worker pool and measure
//! end-to-end request throughput, vs the raw forward-artifact floor.
//! Also times the NDJSON wire codec both ways — the zero-allocation
//! streaming hot path vs the DOM parser it replaced.
//!
//! Run: `cargo bench --bench serve_throughput`

use std::sync::Arc;
use std::time::Duration;

use photonic_dfa::dfa::params::NetState;
use photonic_dfa::runtime::{NativeEngine, StepEngine};
use photonic_dfa::serve::{BatchPolicy, ServeConfig, Server};
use photonic_dfa::tensor::Tensor;
use photonic_dfa::util::benchx::{bench_throughput, black_box, BenchConfig};
use photonic_dfa::util::json_stream::{self, Lexer};
use photonic_dfa::util::rng::Pcg64;

const BURST: usize = 64;

fn requests(d_in: usize, seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Pcg64::seed(seed);
    (0..BURST)
        .map(|_| (0..d_in).map(|_| rng.uniform() as f32).collect())
        .collect()
}

/// NDJSON codec rows: request parse via the streaming lexer (the serve
/// hot path), the DOM parser on the same line (the old path), and the
/// reply serialize+parse round trip.
fn bench_codec(cfg: &BenchConfig, d_in: usize) {
    let mut rng = Pcg64::seed(9);
    let feats: Vec<f32> = (0..d_in).map(|_| rng.uniform() as f32).collect();
    let mut line = String::new();
    json_stream::write_request(&mut line, Some(7), &feats);
    let req = line.trim_end().to_string();

    let mut lexer = Lexer::new();
    let mut x: Vec<f32> = Vec::new();
    let r = bench_throughput(
        &format!("ndjson_parse_request_stream_d{d_in}"),
        cfg,
        d_in as f64,
        "feat",
        || black_box(json_stream::parse_request(&mut lexer, &req, &mut x).unwrap()),
    );
    println!("{}", r.report());

    let r = bench_throughput(
        &format!("ndjson_parse_request_dom_d{d_in}"),
        cfg,
        d_in as f64,
        "feat",
        || black_box(photonic_dfa::util::json::Value::parse(&req).unwrap()),
    );
    println!("{}", r.report());

    let mut logits: Vec<f32> = Vec::new();
    let mut errbuf = String::new();
    let r = bench_throughput(
        &format!("ndjson_reply_round_trip_d{d_in}"),
        cfg,
        d_in as f64,
        "logit",
        || {
            json_stream::write_reply(&mut line, Some(7), 3, &feats);
            black_box(
                json_stream::parse_reply(
                    &mut lexer,
                    line.trim_end(),
                    &mut logits,
                    &mut errbuf,
                )
                .unwrap(),
            )
        },
    );
    println!("{}", r.report());
}

fn main() {
    let cfg = BenchConfig { warmup_iters: 2, min_iters: 10, max_time: Duration::from_secs(2) };
    let engine: Arc<dyn StepEngine> = Arc::new(NativeEngine::new());

    // the wire codec alone, at two request widths
    for d_in in [16, 784] {
        bench_codec(&cfg, d_in);
    }

    for config in ["tiny", "small"] {
        let dims = engine.net_dims(config).unwrap();
        let mut rng = Pcg64::seed(7);
        let state = NetState::init(&dims, &mut rng);

        // floor: the raw fwd artifact at its traced batch size
        let fwd = engine.load(&format!("fwd_{config}")).unwrap();
        let mut inputs: Vec<Tensor> = state.params().to_vec();
        inputs.push(Tensor::randn(&[dims.batch, dims.d_in], 0.5, &mut rng));
        let r = bench_throughput(
            &format!("fwd_artifact_{config}"),
            &cfg,
            dims.batch as f64,
            "req",
            || black_box(fwd.execute(&inputs).unwrap()),
        );
        println!("{}", r.report());

        // the serving stack, a few pool/batch shapes
        for (workers, max_batch) in [(1, dims.batch), (2, dims.batch), (4, 2 * dims.batch)] {
            let server = Server::start(
                &engine,
                config,
                state.params(),
                ServeConfig {
                    workers,
                    policy: BatchPolicy {
                        max_batch,
                        max_wait: Duration::from_millis(1),
                        queue_cap: 4 * BURST,
                    },
                },
            )
            .unwrap();
            let reqs = requests(dims.d_in, 42);
            let r = bench_throughput(
                &format!("serve_{config}_w{workers}_b{max_batch}"),
                &cfg,
                BURST as f64,
                "req",
                || {
                    let tickets: Vec<_> = reqs
                        .iter()
                        .map(|x| server.submit(x.clone()).unwrap())
                        .collect();
                    for t in tickets {
                        black_box(t.wait().unwrap());
                    }
                },
            );
            println!("{}", r.report());
            println!("{}", server.shutdown().report());
        }
    }
}
