//! Bench for Fig. 5(c): the resolution-sweep workload — verifies that the
//! runtime noise/quantisation scalars do not change step latency (a single
//! artifact serves every sweep point) and reports short-sweep accuracies.

use photonic_dfa::dfa::params::NetState;
use photonic_dfa::experiments::fig5c_sweep;
use photonic_dfa::runtime::{self, Backend};
use photonic_dfa::tensor::Tensor;
use photonic_dfa::util::benchx::{bench, BenchConfig};
use photonic_dfa::util::rng::Pcg64;

fn main() {
    let engine = runtime::open("artifacts", Backend::Auto).expect("open step engine");
    let bench_cfg = BenchConfig::default();
    let config = "small";
    println!("backend: {}", engine.platform_name());
    let dims = engine.net_dims(config).unwrap();
    let mut rng = Pcg64::seed(1);
    let state = NetState::init(&dims, &mut rng);
    let (b1, b2) = NetState::init_feedback(&dims, &mut rng);
    let x = Tensor::rand_uniform(&[dims.batch, dims.d_in], 0.0, 1.0, &mut rng);
    let mut y = Tensor::zeros(&[dims.batch, dims.d_out]);
    for r in 0..dims.batch {
        y.set(r, r % dims.d_out, 1.0);
    }
    let n1 = Tensor::randn(&[dims.d_h1, dims.batch], 1.0, &mut rng);
    let n2 = Tensor::randn(&[dims.d_h2, dims.batch], 1.0, &mut rng);
    let dfa = engine.load(&format!("dfa_step_{config}")).unwrap();

    // latency must be flat across the sweep's runtime scalars
    for (label, sigma, bits) in [
        ("clean", 0.0f32, 0.0f32),
        ("sigma_0.098", 0.098, 0.0),
        ("sigma_1.0", 1.0, 0.0),
        ("quant_3b", 0.0, 3.0),
        ("quant_8b", 0.0, 8.0),
    ] {
        let mut inputs: Vec<Tensor> = state.tensors.clone();
        inputs.extend([
            b1.clone(), b2.clone(), x.clone(), y.clone(), n1.clone(), n2.clone(),
            Tensor::scalar(sigma), Tensor::scalar(bits),
            Tensor::scalar(0.01), Tensor::scalar(0.9),
        ]);
        let r = bench(&format!("fig5c/step_{label}"), &bench_cfg, || {
            dfa.execute(&inputs).unwrap()
        });
        println!("{}", r.report());
    }

    // a micro sweep for the accuracy shape (full sweep: resolution_sweep example)
    let pts = fig5c_sweep(engine, config, &[2.0, 4.0, 8.0], 1, 1, 2048, 512, Some(16))
        .unwrap();
    for p in pts {
        println!(
            "fig5c/acc bits={:.1} sigma={:.4} test_acc={:.4}",
            p.bits, p.sigma, p.test_acc
        );
    }
}
