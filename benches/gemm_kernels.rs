//! Raw GEMM kernel trajectory: the register-blocked `matmul` micro-kernel
//! and its transpose-operand variants on the paper's MNIST layer shapes,
//! across thread caps 1 / 2 / 4 / all-cores.
//!
//! Writes the machine-readable record CI commits on main pushes:
//!
//! ```text
//! cargo bench --bench gemm_kernels -- --json BENCH_GEMM.json
//! ```

use photonic_dfa::tensor::ops::{matmul, matmul_at, matmul_bt, ThreadCapGuard};
use photonic_dfa::tensor::Tensor;
use photonic_dfa::util::benchx::{
    bench_throughput, json_out_arg, BenchConfig, BenchRecords,
};
use photonic_dfa::util::json::Value;
use photonic_dfa::util::rng::Pcg64;
use photonic_dfa::util::threads;

fn main() {
    let cfg = BenchConfig {
        warmup_iters: 2,
        min_iters: 5,
        max_time: std::time::Duration::from_secs(2),
    };
    let mut records = BenchRecords::new("gemm_kernels");
    let mut rng = Pcg64::seed(7);
    let all_cores = threads::available();

    // forward-activation GEMM of the mnist config: [batch, d_in] @
    // [d_in, d_h1] = (64 x 784) · (784 x 800) — large enough to cross
    // PAR_THRESHOLD, so the thread-cap rows exercise the row split.
    let (m, k, n) = (64usize, 784usize, 800usize);
    let a = Tensor::rand_uniform(&[m, k], -1.0, 1.0, &mut rng);
    let b = Tensor::rand_uniform(&[k, n], -1.0, 1.0, &mut rng);
    let macs = (m * k * n) as f64;
    // caps 1/2/4 plus all-cores when that is a distinct count (keeps the
    // row names unique on 4-core machines)
    let mut caps = vec![1usize, 2, 4];
    if !caps.contains(&all_cores) {
        caps.push(all_cores);
    }
    for &threads in &caps {
        let _guard = ThreadCapGuard::set(threads);
        let r = bench_throughput(
            &format!("gemm/matmul_{m}x{k}x{n}_threads{threads}"),
            &cfg,
            macs,
            "MAC",
            || matmul(&a, &b).unwrap(),
        );
        println!("{}", r.report());
        records.push(
            &r,
            vec![
                ("kernel", Value::str("matmul")),
                ("m", Value::Number(m as f64)),
                ("k", Value::Number(k as f64)),
                ("n", Value::Number(n as f64)),
                ("threads", Value::Number(threads as f64)),
            ],
        );
    }

    // DFA backward shapes for the transpose-operand kernels, at one
    // thread and all cores:
    //   matmul_bt — error projection e @ Bᵀ: (64 x 10) · (800 x 10)ᵀ
    //   matmul_at — weight update aᵀ @ δ:   (64 x 784)ᵀ · (64 x 800)
    let e = Tensor::rand_uniform(&[64, 10], -1.0, 1.0, &mut rng);
    let bmat = Tensor::rand_uniform(&[800, 10], -1.0, 1.0, &mut rng);
    let act = Tensor::rand_uniform(&[64, 784], 0.0, 1.0, &mut rng);
    let delta = Tensor::rand_uniform(&[64, 800], -1.0, 1.0, &mut rng);
    let scale_caps = if all_cores == 1 { vec![1usize] } else { vec![1, all_cores] };
    for &threads in &scale_caps {
        let _guard = ThreadCapGuard::set(threads);
        let r = bench_throughput(
            &format!("gemm/matmul_bt_64x10x800_threads{threads}"),
            &cfg,
            (64 * 10 * 800) as f64,
            "MAC",
            || matmul_bt(&e, &bmat).unwrap(),
        );
        println!("{}", r.report());
        records.push(
            &r,
            vec![
                ("kernel", Value::str("matmul_bt")),
                ("m", Value::Number(64.0)),
                ("k", Value::Number(10.0)),
                ("n", Value::Number(800.0)),
                ("threads", Value::Number(threads as f64)),
            ],
        );

        let r = bench_throughput(
            &format!("gemm/matmul_at_784x64x800_threads{threads}"),
            &cfg,
            (784 * 64 * 800) as f64,
            "MAC",
            || matmul_at(&act, &delta).unwrap(),
        );
        println!("{}", r.report());
        records.push(
            &r,
            vec![
                ("kernel", Value::str("matmul_at")),
                ("m", Value::Number(784.0)),
                ("k", Value::Number(64.0)),
                ("n", Value::Number(800.0)),
                ("threads", Value::Number(threads as f64)),
            ],
        );
    }

    if let Some(path) = json_out_arg() {
        records.write(&path).expect("write bench record");
        println!("gemm_kernels: wrote {} rows to {path}", records.len());
    }
}
