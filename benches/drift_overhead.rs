//! Device-lifetime bench: what drift tracking and online recalibration
//! cost per dispatch.
//!
//! Times the `fwd` artifact of the tiny config on a multi-tile photonic
//! bank under three lifetime regimes:
//!
//! * `static`      — drift disabled: the pre-lifetime baseline
//! * `tracking`    — thermal walk active but always under the threshold:
//!                   pays the per-dispatch advance + phase refresh only
//! * `recalibrate` — walk hot enough to cross the threshold every drift
//!                   tick: the steady-state amortized cost of the online
//!                   recalibration scheduler (the §4 sweep + probe lock)
//!
//! Writes the machine-readable record CI commits on main pushes:
//!
//! ```text
//! cargo bench --bench drift_overhead -- --json BENCH_DRIFT.json
//! ```

use photonic_dfa::dfa::params::NetState;
use photonic_dfa::runtime::{PhotonicEngine, PhysicsConfig, StepEngine};
use photonic_dfa::tensor::Tensor;
use photonic_dfa::util::benchx::{bench, json_out_arg, BenchConfig, BenchRecords};
use photonic_dfa::util::json::Value;
use photonic_dfa::util::rng::Pcg64;

fn main() {
    let mut records = BenchRecords::new("drift_overhead");
    let cfg = BenchConfig {
        warmup_iters: 2,
        min_iters: 20,
        max_time: std::time::Duration::from_secs(2),
    };

    // multi-tile bank so the dispatch itself does real tiling work; the
    // drift knobs are the only difference between the arms
    let base = PhysicsConfig {
        bank_rows: 16,
        bank_cols: 12,
        ..PhysicsConfig::ideal()
    };
    let arms = [
        ("static", 0.0, 0.0),
        // weight err ≈ 1e-7·122·√ticks: never reaches the threshold even
        // over millions of in-bench dispatches
        ("tracking", 1e-7, 0.05),
        // err/tick ≈ 1.2: every drift tick fires the full recal protocol
        ("recalibrate", 1e-2, 0.05),
    ];
    for (label, rate, threshold) in arms {
        let physics = PhysicsConfig {
            drift_rate: rate,
            recal_threshold: threshold,
            ..base
        };
        let engine = PhotonicEngine::open("artifacts", physics).unwrap();
        let fwd = engine.load("fwd_tiny").unwrap();
        let dims = engine.net_dims("tiny").unwrap();
        let mut rng = Pcg64::seed(1);
        let state = NetState::init(&dims, &mut rng);
        let mut inputs: Vec<Tensor> = state.tensors[..6].to_vec();
        inputs.push(Tensor::rand_uniform(
            &[dims.batch, dims.d_in],
            0.0,
            1.0,
            &mut rng,
        ));

        let r = bench(&format!("drift/fwd_tiny_{label}"), &cfg, || {
            fwd.execute(&inputs).unwrap()
        });
        println!("{}", r.report());
        let t = engine.telemetry();
        println!(
            "drift/telemetry_{label}: {} cycles, {} recals ({} recal cycles), \
             weight err {:.4}",
            t.cycles, t.recal_events, t.recal_cycles, t.drift_err,
        );
        records.push(
            &r,
            vec![
                ("net", Value::str("tiny")),
                ("regime", Value::str(label)),
                ("drift_rate", Value::Number(rate)),
                ("recal_events", Value::Number(t.recal_events as f64)),
                ("recal_cycles", Value::Number(t.recal_cycles as f64)),
                ("threads", Value::Number(1.0)),
            ],
        );
    }

    if let Some(path) = json_out_arg() {
        records.write(&path).unwrap();
        println!("wrote {path}");
    }
}
