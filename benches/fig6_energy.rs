//! Bench for Fig. 6: the optimal-E_op sweep — prints the table rows the
//! figure plots and times the analytic model (it backs interactive tools,
//! so planning latency matters).

use photonic_dfa::energy::components::MrrTuning;
use photonic_dfa::energy::model::ArchitectureModel;
use photonic_dfa::energy::sweep::optimal_for_cells;
use photonic_dfa::experiments::fig6_rows;
use photonic_dfa::util::benchx::{bench, BenchConfig};

fn main() {
    let cfg = BenchConfig::default();

    println!("fig6 rows (cells, E_op heater pJ, E_op trimmed pJ):");
    for (cells, h, t) in fig6_rows(25, 100_000, 14) {
        println!("fig6/row {cells:>7} {:>8.3} {:>8.3}", h * 1e12, t * 1e12);
    }

    let base = ArchitectureModel::paper(MrrTuning::Trimmed);
    let r = bench("fig6/optimal_for_1000_cells", &cfg, || {
        optimal_for_cells(base, 1000, 5).unwrap()
    });
    println!("{}", r.report());

    let r = bench("fig6/full_sweep_14pts", &cfg, || {
        fig6_rows(25, 100_000, 14)
    });
    println!("{}", r.report());

    let r = bench("fig6/single_eop_eval", &cfg, || {
        base.with_dims(50, 20).energy_per_op()
    });
    println!("{}", r.report());
}
